"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:

  compute    = HLO_FLOPs / (chips * 197e12 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s)
  collective = collective_bytes / (chips * links * 50e9 B/s)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (already
per-partition under SPMD). collective_bytes is parsed out of the
compiled HLO text: we sum the (per-device) output-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, weighting all-reduce x2 (reduce-scatter +
all-gather phases of a ring all-reduce).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link
ICI_LINKS = 2              # usable links per axis-neighbour pair (2D torus)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g.  bf16[16,2048,128]{2,1,0}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective traffic by op kind, from (SPMD) HLO text."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)",
                     line)
        if not m:
            continue
        op = m.group(2)
        # strip fusion suffixes e.g. all-reduce-start
        base = op.replace("-start", "").replace("-done", "")
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting async pairs
        out[base] += _shape_bytes(m.group(1))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    hbm_bytes: float              # per device
    coll_bytes: float             # per device (weighted)
    coll_by_kind: Dict[str, int]
    n_devices: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_by_kind": self.coll_by_kind,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "t_lower_bound_s": self.step_time_lower_bound,
        }


def analyze(compiled, n_devices: int,
            hlo_text: Optional[str] = None) -> Roofline:
    """Build the roofline from a compiled executable.

    Uses the trip-count-aware HLO walker (hlo_analysis.py): XLA's own
    cost_analysis() counts while-loop bodies ONCE, under-reporting
    scanned models by ~n_layers x accum; the walker multiplies loop
    bodies by their recovered trip counts. Raw cost_analysis numbers are
    preserved separately by the caller for cross-checking.
    """
    from .hlo_analysis import analyze_hlo
    text = hlo_text if hlo_text is not None else compiled.as_text()
    tot = analyze_hlo(text)
    return Roofline(flops=tot.flops, hbm_bytes=tot.hbm_bytes,
                    coll_bytes=tot.weighted_coll_bytes,
                    coll_by_kind={k: int(v)
                                  for k, v in tot.coll_bytes.items()},
                    n_devices=n_devices)


def analyze_raw(compiled, n_devices: int,
                hlo_text: Optional[str] = None) -> Roofline:
    """Roofline from XLA cost_analysis() + flat HLO grep (no loop
    multipliers) — kept for comparison with `analyze`."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    weighted = sum(v * (2 if k == "all-reduce" else 1)
                   for k, v in coll.items())
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=float(weighted),
                    coll_by_kind=coll, n_devices=n_devices)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for a train
    step; 2*N*D for prefill; 2*N_active per token for decode."""
    h, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    hd = cfg.resolved_head_dim
    attn_params = h * (cfg.n_heads * hd + 2 * cfg.kv_heads * hd) \
        + cfg.n_heads * hd * h
    if cfg.family == "moe":
        ffn_active = 3 * h * cfg.moe.expert_ff * cfg.moe.top_k
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * h
        attn_params = 0
        ffn_active = d_in * (2 * d_in + 2 * s.d_state) + d_in * h
    elif cfg.family == "hybrid":
        W = cfg.hybrid.lru_width or h
        # 2/3 rec layers + 1/3 attn; every layer has an MLP
        rec = 3 * h * W + 2 * (W // 8) * W
        ffn_active = 3 * h * cfg.d_ff + (2 * rec + attn_params) / 3.0
        attn_params = 0
    else:
        mult = 3 if cfg.activation == "swiglu" else 2
        ffn_active = mult * h * cfg.d_ff
    n_active = L * (attn_params + ffn_active) + 2 * V * h
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    per_tok = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
    total = per_tok * n_active * tokens
    if (cfg.family == "audio" and cfg.encdec is not None
            and shape.kind != "decode"):
        # encoder runs over n_audio_frames once per sequence, plus one
        # cross-attention block per decoder layer over those frames
        # (decode steps reuse the cached encoder output — no new FLOPs).
        enc_params = cfg.encdec.n_enc_layers * (attn_params + ffn_active)
        xattn = L * attn_params
        enc_tokens = shape.global_batch * cfg.encdec.n_audio_frames
        total += per_tok * (enc_params + xattn) * enc_tokens
    return total
