"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers
against these. Modality frontends are stubs: `patch_embeds` /  `frames`
are the precomputed embeddings the real ViT/conv frontend would emit.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from ..models.model import init_cache, init_params
from ..training.optimizer import AdamW
from ..training.train_step import TrainState

SDS = jax.ShapeDtypeStruct


def dryrun_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Production-run overrides: bf16 params, scanned+remat layers,
    chunked (flash-style) attention; full-attention archs get the
    sliding-window variant for the 500k decode shape."""
    kw = dict(param_dtype="bfloat16", attn_impl="chunked",
              scan_layers=True, remat=True)
    if (shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid")
            and cfg.sliding_window is None):
        kw["sliding_window"] = 8192
    return cfg.with_(**kw)


def accum_for(cfg: ModelConfig, shape: InputShape,
              data_ways: int = 16) -> int:
    """Gradient-accumulation depth for train shapes: keep the per-device
    micro-batch near ~1 sequence for giant models, a few for mid-size."""
    if shape.kind != "train":
        return 1
    per_dev_seqs = max(1, shape.global_batch // data_ways)
    act_cost = cfg.n_layers * cfg.d_model          # rough residual bytes/tok
    if act_cost >= 126 * 16384:                    # 405B class
        return per_dev_seqs
    if act_cost >= 28 * 4096:                      # ~6-12B class
        return min(4, per_dev_seqs)
    return min(2, per_dev_seqs)


def optimizer_for(cfg: ModelConfig) -> AdamW:
    """bf16 optimizer states for the 405B config (HBM fit — DESIGN.md)."""
    big = cfg.arch_id == "llama3-405b"
    return AdamW(lr=3e-4, state_dtype="bfloat16" if big else "float32")


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for one step of `shape.kind`."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": SDS((B, S), jnp.int32),
            "labels": SDS((B, S), jnp.int32),
        }
        if cfg.family == "vlm":
            P = max(1, int(S * cfg.vlm.patches_per_seq_frac))
            specs["patch_embeds"] = SDS((B, P, cfg.vlm.vision_dim),
                                        jnp.bfloat16)
            specs["patch_pos"] = SDS((B, P), jnp.int32)
        if cfg.family == "audio":
            specs["frames"] = SDS((B, cfg.encdec.n_audio_frames,
                                   cfg.d_model), jnp.bfloat16)
        if shape.kind == "prefill":
            specs.pop("labels")
        return specs
    # decode: ONE new token per stream + cache of seq_len capacity
    return {"tokens": SDS((B,), jnp.int32)}


def abstract_state(cfg: ModelConfig, opt: AdamW) -> TrainState:
    """eval_shape'd TrainState — no device allocation."""
    def build(key):
        params = init_params(key, cfg)
        return TrainState(params=params, opt=opt.init(params))
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.random.PRNGKey(0))


def abstract_cache(cfg: ModelConfig, shape: InputShape):
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           jnp.bfloat16))
