"""End-to-end training launcher — thin wrapper over the unified
`repro.api` Engine.

There is ONE driver loop (`Engine.train`): heterogeneous batches from a
video-length distribution, the selected Strategy planning every global
batch on a background host thread (async producer-consumer, paper §5.2),
and the executor dispatching CP groups with Ring Attention from the
cluster's group/executable pool. `--mode` (alias `--strategy`) selects
the parallelism policy from the registry:

  * `static` / `megatron` / `deepspeed` — fixed-degree baselines;
  * `dhp` / `dhp-faithful`              — the paper's dynamic system;
  * `bruteforce`                        — exact Stage-2 solver (tiny runs);
  * `oracle`                            — plans with measured costs.

CPU demo (run with multiple host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch internvl3-2b \\
      --mode dhp --steps 20 --reduced

The old `run_static` / `run_dhp` entry points remain as deprecated shims
that route through the same Engine loop.
"""
from __future__ import annotations

import warnings

from ..api.cli import build_parser, run  # noqa: F401  (re-export)
from ..api.cli import main as _api_main


def main(argv=None):
    """Legacy launcher entry: keeps the pre-API default of `--mode
    static` (the `repro-train` CLI defaults to dhp)."""
    _api_main(argv, default_strategy="static")


def _run_with_strategy(args, strategy: str):
    args.strategy = strategy
    if not hasattr(args, "mode"):
        args.mode = strategy
    return [m.loss for m in run(args)]


def run_static(args):
    """Deprecated: use `repro.api.Engine(strategy='static').train()`."""
    warnings.warn(
        "run_static is deprecated; use repro.api.Engine with "
        "strategy='static'", DeprecationWarning, stacklevel=2)
    return _run_with_strategy(args, "static")


def run_dhp(args):
    """Deprecated: use `repro.api.Engine(strategy='dhp').train()`."""
    warnings.warn(
        "run_dhp is deprecated; use repro.api.Engine with "
        "strategy='dhp'", DeprecationWarning, stacklevel=2)
    return _run_with_strategy(args, "dhp")


if __name__ == "__main__":
    main()
