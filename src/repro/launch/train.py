"""End-to-end training driver.

Two modes:
  * `--mode static`  — plain pjit data-parallel training on the demo
    mesh (the Megatron/DeepSpeed-style baseline).
  * `--mode dhp`     — the paper's system: heterogeneous batches from a
    video-length distribution, the DHP scheduler planning every global
    batch (async, producer-consumer), the executor running CP groups
    with Ring Attention, group/executable pooling.

CPU demo (run with multiple host devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python -m repro.launch.train --arch internvl3-2b \\
      --mode dhp --steps 20 --reduced
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import INPUT_SHAPES, get_config
from ..core import (CostModel, DHPScheduler, Profiler, analytic_coeffs)
from ..core.executor import DHPExecutor
from ..data.pipeline import HeterogeneousLoader, synthetic_batch
from ..models.model import init_params
from ..training.checkpoint import save
from ..training.optimizer import AdamW, cosine_schedule
from ..training.train_step import TrainState, make_train_step
from .mesh import make_demo_mesh


def run_static(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_demo_mesh()
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=0)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    state = TrainState(params=params, opt=opt.init(params))
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"],
                                seq_len=args.seq_len,
                                global_batch=args.batch)
    losses = []
    for i in range(args.steps):
        np_batch = synthetic_batch(cfg, shape, seed=args.seed + i)
        batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        print(f"step {i:3d} loss={loss:.4f} "
              f"({time.perf_counter() - t0:.2f}s)")
    if args.checkpoint:
        save(args.checkpoint, state.params)
        print("saved", args.checkpoint)
    return losses


def run_dhp(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "vlm":
        # the loader feeds token streams (vision tokens already counted
        # in the SeqInfo lengths); run the LM decoder — same convention
        # as examples/dhp_training.py
        cfg = cfg.with_(family="dense", vlm=None)
    n_ranks = len(jax.devices())
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))

    coeffs = analytic_coeffs(
        hidden=cfg.d_model, n_layers=cfg.n_layers,
        n_heads=max(cfg.n_heads, 1), kv_heads=max(cfg.kv_heads, 1),
        ffn=max(cfg.d_ff, 1), vocab=cfg.vocab)
    # memory pressure knob for the demo: budget in tokens-equivalents
    coeffs = dataclasses.replace(coeffs, m_ms=0.0, m_token=1.0)
    cm = CostModel(coeffs)
    sched = DHPScheduler(cm, n_ranks, mem_budget=args.mem_budget)
    ex = DHPExecutor(cfg)

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    state = TrainState(params=params, opt=opt.init(params))
    loader = iter(HeterogeneousLoader(
        args.dataset, args.batch, cfg.vocab, seed=args.seed,
        max_tokens=args.seq_len, tokens_per_frame=16))

    @jax.jit
    def apply_update(state, grads):
        p, o = opt.update(grads, state.opt, state.params)
        return TrainState(p, o)

    data = next(loader)
    sched.prepare(data.infos)          # async scheduling (paper §5.2)
    losses = []
    for i in range(args.steps):
        plan = sched.collect()
        next_data = next(loader)
        sched.prepare(next_data.infos)  # overlap next plan with compute
        t0 = time.perf_counter()
        loss, grads = ex.run_plan(state.params, plan, data)
        state = apply_update(state, grads)
        losses.append(float(loss))
        print(f"step {i:3d} loss={float(loss):.4f} "
              f"groups={plan.degree_histogram} "
              f"sched={plan.schedule_ms:.0f}ms "
              f"({time.perf_counter() - t0:.2f}s)")
        data = next_data
    print("executable pool:", ex.pool.stats)
    if args.checkpoint:
        save(args.checkpoint, state.params)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--mode", choices=("static", "dhp"), default="static")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--dataset", default="openvid")
    ap.add_argument("--mem-budget", type=float, default=1024.0,
                    help="per-rank activation budget in tokens (demo)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()
    if args.mode == "static":
        run_static(args)
    else:
        run_dhp(args)


if __name__ == "__main__":
    main()
