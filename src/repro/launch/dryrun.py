import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config)  # noqa: E402
from ..models.model import forward, prefill        # noqa: E402
from ..parallel.act_sharding import activation_constraints  # noqa: E402
from ..parallel.sharding import (batch_specs, cache_specs, data_axes,
                                 param_specs)      # noqa: E402
from ..serving.serve_step import make_serve_step   # noqa: E402
from ..training.train_step import TrainState, make_train_step  # noqa: E402
from .mesh import make_production_mesh             # noqa: E402
from .roofline import analyze, model_flops         # noqa: E402
from .specs import (abstract_cache, abstract_params, abstract_state,
                    accum_for, dryrun_config, input_specs,
                    optimizer_for)  # noqa: E402

"""Multi-pod dry-run: lower + compile EVERY (arch x input-shape) on the
single-pod (16,16) and multi-pod (2,16,16) production meshes, printing
memory_analysis() and cost_analysis() and writing a JSON record per pair
for §Dry-run / §Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""


def _shard(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool,
               fsdp: bool = True, donate: bool = True,
               variant: Optional[dict] = None):
    """Returns (lowered, compiled, meta) for one (arch, shape, mesh).

    `variant` — §Perf hillclimb switches (all default off = baseline):
      moe_dispatch: "sort"|"einsum"   MoE dispatch formulation
      sp: bool                        Megatron-SP sequence-sharded acts
      grad_rs: bool                   reduce-scatter grad accumulator
      accum: int                      override gradient-accumulation depth
      tp: int                         single-pod mesh split (data=256/tp)
    """
    variant = variant or {}
    tp = int(variant.get("tp") or 16)
    mesh = make_production_mesh(multi_pod=multi_pod, dp=256 // tp, tp=tp)
    shape = INPUT_SHAPES[shape_name]
    cfg = dryrun_config(get_config(arch), shape)
    if variant.get("moe_dispatch") and cfg.moe is not None:
        import dataclasses as _dc
        cfg = cfg.with_(moe=_dc.replace(cfg.moe,
                                        dispatch=variant["moe_dispatch"]))
    daxes = data_axes(mesh)

    with mesh, activation_constraints(
            mesh, daxes, batch_sharded=shape.global_batch > 1,
            sp=bool(variant.get("sp"))):
        if shape.kind == "train":
            opt = optimizer_for(cfg)
            data_ways = mesh.shape["data"] * mesh.shape.get("pod", 1)
            state = abstract_state(cfg, opt)
            pspecs = param_specs(state.params, cfg, fsdp=fsdp, mesh=mesh)
            grad_constraint = None
            if variant.get("grad_rs"):
                def grad_constraint(g, _ps=pspecs, _mesh=mesh):
                    return jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(
                            x, NamedSharding(_mesh, s)), g, _ps)
            step = make_train_step(
                cfg, opt,
                accum_steps=variant.get("accum") or accum_for(
                    cfg, shape, data_ways),
                grad_constraint=grad_constraint)
            sspecs = TrainState(
                params=pspecs,
                opt=type(state.opt)(step=P(), m=pspecs, v=pspecs))
            bspecs = {k: v for k, v in
                      batch_specs(cfg, shape, mesh).items()}
            inputs = input_specs(cfg, shape)
            bspecs = {k: bspecs[k] for k in inputs}
            jitted = jax.jit(
                step,
                in_shardings=(_shard(mesh, sspecs), _shard(mesh, bspecs)),
                out_shardings=(_shard(mesh, sspecs),
                               NamedSharding(mesh, P())),
                donate_argnums=(0,) if donate else ())
            lowered = jitted.lower(state, inputs)
        elif shape.kind == "prefill":
            params = abstract_params(cfg)
            pspecs = param_specs(params, cfg, fsdp=fsdp, mesh=mesh)
            inputs = input_specs(cfg, shape)
            bspecs = {k: v for k, v in
                      batch_specs(cfg, shape, mesh).items()
                      if k in inputs}

            if cfg.family in ("dense", "moe", "vlm"):
                def fn(p, batch):
                    return prefill(p, cfg, batch,
                                   cache_len=shape.seq_len)
            else:
                def fn(p, batch):
                    logits, _ = forward(p, cfg, batch)
                    return logits[:, -1:]
            jitted = jax.jit(
                fn, in_shardings=(_shard(mesh, pspecs),
                                  _shard(mesh, bspecs)))
            lowered = jitted.lower(params, inputs)
        else:  # decode
            params = abstract_params(cfg)
            pspecs = param_specs(params, cfg, fsdp=fsdp, mesh=mesh)
            cache = abstract_cache(cfg, shape)
            cspecs = cache_specs(cfg, shape, mesh)
            tspec = P(daxes if shape.global_batch > 1 else None)
            serve = make_serve_step(cfg)
            jitted = jax.jit(
                serve,
                in_shardings=(_shard(mesh, pspecs), _shard(mesh, cspecs),
                              NamedSharding(mesh, tspec)),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params, cache,
                                   input_specs(cfg, shape)["tokens"])

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    meta = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "n_devices": mesh.size, "compile_s": compile_s,
            "kind": shape.kind}
    return lowered, compiled, meta


def run_pair(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, fsdp: bool = True, quiet: bool = False,
             variant: Optional[dict] = None, tag: str = "") -> dict:
    lowered, compiled, meta = lower_pair(arch, shape_name,
                                         multi_pod=multi_pod, fsdp=fsdp,
                                         variant=variant)
    if variant:
        meta["variant"] = variant
    mem = compiled.memory_analysis()
    rec = dict(meta)
    rec["memory"] = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    text = compiled.as_text()
    roof = analyze(compiled, meta["n_devices"], hlo_text=text)
    rec["roofline"] = roof.as_dict()
    from .roofline import analyze_raw
    rec["roofline_raw_costanalysis"] = analyze_raw(
        compiled, meta["n_devices"], hlo_text=text).as_dict()
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mf = model_flops(cfg, shape)
    rec["model_flops_total"] = mf
    dev_flops = roof.flops
    rec["useful_flops_ratio"] = (
        mf / meta["n_devices"] / dev_flops if dev_flops else None)
    if not quiet:
        print(f"== {arch} x {shape_name} [{rec['mesh']}] "
              f"compile={meta['compile_s']:.1f}s")
        print("   memory_analysis:", rec["memory"])
        print("   roofline:", {k: (f"{v:.3e}" if isinstance(v, float)
                                   else v)
                               for k, v in rec["roofline"].items()
                               if not isinstance(v, dict)})
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir,
                      f"{arch}__{shape_name}__{rec['mesh']}{tag}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    pairs = []
    archs = ASSIGNED_ARCHS if args.all else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    failures = []
    for a, s, mp in pairs:
        mesh_name = "2x16x16" if mp else "16x16"
        fn = os.path.join(args.out, f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(fn):
            print(f"-- skip {a} x {s} [{mesh_name}] (exists)")
            continue
        try:
            run_pair(a, s, multi_pod=mp, out_dir=args.out)
        except Exception as e:   # noqa: BLE001
            failures.append((a, s, mp, repr(e)))
            print(f"!! FAIL {a} x {s} [{mesh_name}]: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-runs compiled OK")


if __name__ == "__main__":
    main()
