import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse     # noqa: E402

from .dryrun import lower_pair                     # noqa: E402
from .hlo_analysis import top_contributors         # noqa: E402
from .roofline import analyze                      # noqa: E402

"""Per-op roofline profile of one (arch x shape x mesh) dry-run — the
'profiler' of the §Perf hypothesis loop (no real TPU, so the profile is
the trip-count-weighted HLO op breakdown).

  PYTHONPATH=src python -m repro.launch.profile_pair \
      --arch llama3-405b --shape train_4k [--by hbm|flops|coll] [-k 30]
"""


def fmt(x: float) -> str:
    for unit, div in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6),
                      ("K", 1e3)):
        if abs(x) >= div:
            return f"{x / div:8.2f}{unit}"
    return f"{x:8.0f} "


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--by", default="hbm", choices=["hbm", "flops", "coll"])
    ap.add_argument("-k", type=int, default=30)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--moe-dispatch", default=None)
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--grad-rs", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tp", type=int, default=None)
    args = ap.parse_args()

    variant = {k: v for k, v in (("moe_dispatch", args.moe_dispatch),
                                 ("sp", args.sp), ("grad_rs", args.grad_rs),
                                 ("accum", args.accum),
                                 ("tp", args.tp)) if v}
    lowered, compiled, meta = lower_pair(
        args.arch, args.shape, multi_pod=args.multi_pod,
        fsdp=not args.no_fsdp, variant=variant)
    text = compiled.as_text()
    roof = analyze(compiled, meta["n_devices"], hlo_text=text)
    print(f"== {args.arch} x {args.shape} [{meta['mesh']}] "
          f"variant={variant} compile={meta['compile_s']:.1f}s")
    mem = compiled.memory_analysis()
    print(f"   args={getattr(mem, 'argument_size_in_bytes', 0)/1e9:.2f}GB "
          f"temp={getattr(mem, 'temp_size_in_bytes', 0)/1e9:.2f}GB "
          f"out={getattr(mem, 'output_size_in_bytes', 0)/1e9:.2f}GB")
    d = roof.as_dict()
    print("   roofline:", {k: (f"{v:.3e}" if isinstance(v, float) else v)
                           for k, v in d.items() if not isinstance(v, dict)})
    print(f"\n top {args.k} contributors by {args.by} "
          f"(per device, trip-weighted):")
    print(f" {'flops':>9s} {'hbm':>9s} {'coll':>9s} {'x':>6s}  op  shape")
    for r in top_contributors(text, k=args.k, by=args.by):
        print(f" {fmt(r['flops'])} {fmt(r['hbm_bytes'])} "
              f"{fmt(r['coll_bytes'])} {r['count']:6.0f}  "
              f"{r['op']}  {r['shape']}")


if __name__ == "__main__":
    main()
