"""Trip-count-aware HLO analysis.

XLA's `compiled.cost_analysis()` counts each `while` body ONCE, so any
scan-over-layers model under-reports FLOPs / bytes / collective traffic
by ~n_layers x accum_steps. This module re-derives the roofline inputs
from the partitioned HLO text with loop multipliers:

  * computations are parsed into {name -> instructions};
  * `while` ops contribute their body's totals x trip count (recovered
    from the `constant(N)` in the loop's condition computation);
  * `fusion` ops contribute their called computation's DOT FLOPs but not
    its internal memory traffic (fusion internals stay in registers);
  * dot FLOPs = 2 * prod(output dims) * prod(lhs contracting dims);
  * memory traffic = operand + output bytes of each materialized
    instruction (top-level ops and fusion boundaries — the HBM picture);
  * collective bytes by kind from output shapes (async -start/-done
    pairs counted once).

All numbers are PER DEVICE (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)"
    r"\((.*)$")
_COMP_HDR_RE = re.compile(r"^(%[\w.\-]+|ENTRY\s+%?[\w.\-]+)\s*\(.*\{$")


def _shape_dims(shape: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(shape: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if not line.startswith(" ") and line.rstrip().endswith("{"):
            m = re.match(r"^(ENTRY\s+)?(%?[\w.\-]+)", line)
            if m:
                name = m.group(2)
                cur = Computation(name=name, instrs=[], shapes={})
                comps[name] = cur
                if m.group(1):
                    comps["__ENTRY__"] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        instr = Instr(name=im.group(1), shape=im.group(2),
                      op=im.group(3), rest=im.group(4))
        cur.instrs.append(instr)
        cur.shapes[instr.name] = instr.shape
    return comps


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = 1
    dims_list = _shape_dims(instr.shape)
    if not dims_list:
        return 0.0
    for d in dims_list[0][1]:
        out_elems *= d
    # the lhs operand is the first %ref; depending on the XLA version the
    # HLO text prints operands with ("f32[8,8]{1,0} %Arg_0.1") or without
    # a type prefix, so search rather than anchor at the start.
    m = re.search(r"(%[\w.\-]+)", instr.rest)
    contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if not m or not contract:
        return 0.0
    lhs_shape = comp.shapes.get(m.group(1))
    if lhs_shape is None:
        return 0.0
    lhs_dims = _shape_dims(lhs_shape)
    if not lhs_dims:
        return 0.0
    k = 1
    for idx in contract.group(1).split(","):
        if idx:
            k *= lhs_dims[0][1][int(idx)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> int:
    """Largest s32 constant in the loop condition — the trip count for
    jax.lax.scan-style 0..N loops."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
        m = re.search(r"constant\((\d+)\)", ins.rest)
        if m:
            best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class HloTotals:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "HloTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult

    @property
    def weighted_coll_bytes(self) -> float:
        return sum(v * (2 if k == "all-reduce" else 1)
                   for k, v in self.coll_bytes.items())


_CALL_RE = re.compile(r"(?:calls|body|to_apply)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")


def _fusion_hbm(instr: Instr, comp: Computation,
                comps: Dict[str, Computation]) -> float:
    """HBM traffic of one top-level fusion op, slice- and alias-aware.

    Naive counting treats every operand/output as a full read/write; but
    a fusion whose body merely `dynamic-slice`s a big while-carried
    buffer reads only the slice, and a fusion rooted in a
    `dynamic-update-slice` of a parameter writes only the updated window
    (XLA emits it in place).  This is exactly the scan-over-layers
    stacked-activation pattern, and without this correction the memory
    roofline term is inflated by O(n_layers).
    """
    call = _CALL_RE.search(instr.rest)
    body = comps.get(call.group(1)) if call else None
    operands = re.findall(r"(%[\w.\-]+)", instr.rest)
    if body is None:
        total = _shape_bytes(instr.shape)
        for opname in operands:
            s = comp.shapes.get(opname)
            if s:
                total += _shape_bytes(s)
        return total

    # map body parameter index -> uses
    param_of: Dict[str, int] = {}
    uses: Dict[int, List[Instr]] = {}
    for ins in body.instrs:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", "parameter(" + ins.rest)
            idx = int(m.group(1)) if m else len(param_of)
            param_of[ins.name] = idx
            uses[idx] = []
    for ins in body.instrs:
        if ins.op == "parameter":
            continue
        for ref in re.findall(r"(%[\w.\-]+)", ins.rest):
            if ref in param_of:
                uses[param_of[ref]].append(ins)

    root = body.instrs[-1] if body.instrs else None
    # unwrap a trailing convert/bitcast chain to find the true producer
    true_root = root
    while true_root is not None and true_root.op in ("convert", "bitcast",
                                                     "copy"):
        m = re.match(r"\s*(%[\w.\-]+)", true_root.rest)
        prod = m.group(1) if m else None
        nxt = next((i for i in body.instrs if i.name == prod), None)
        if nxt is None:
            break
        true_root = nxt

    by_name = {i.name: i for i in body.instrs}

    def _trace_to_param(name: str) -> Optional[str]:
        """Follow unary convert/bitcast/copy chains back to a parameter."""
        for _ in range(8):
            ins2 = by_name.get(name)
            if ins2 is None:
                return None
            if ins2.op == "parameter":
                return ins2.name
            if ins2.op not in ("convert", "bitcast", "copy"):
                return None
            m = re.match(r"\s*(%[\w.\-]+)", ins2.rest)
            if not m:
                return None
            name = m.group(1)
        return None

    dus_param = -1      # parameter aliased by an in-place root DUS
    out_bytes = _shape_bytes(instr.shape)
    if true_root is not None and true_root.op == "dynamic-update-slice":
        ops = re.findall(r"(%[\w.\-]+)", true_root.rest)
        src = _trace_to_param(ops[0]) if ops else None
        if src is not None:
            upd_shape = body.shapes.get(ops[1]) if len(ops) > 1 else None
            upd = _shape_bytes(upd_shape) if upd_shape else 0
            dus_param = param_of[src]
            out_bytes = upd          # in-place: write the window only

    total = float(out_bytes)
    for pos, opname in enumerate(operands):
        s = comp.shapes.get(opname)
        if not s:
            continue
        full = _shape_bytes(s)
        u = uses.get(pos, [])
        if pos == dus_param:
            # aliased buffer: no read of the untouched region
            contrib = 0
        elif u and all(i.op == "dynamic-slice" for i in u):
            contrib = sum(_shape_bytes(i.shape) for i in u)
        else:
            contrib = full
        total += contrib
    return total


def _analyze_comp(name: str, comps: Dict[str, Computation],
                  memo: Dict[str, HloTotals],
                  in_fusion: bool = False) -> HloTotals:
    key = name + ("#f" if in_fusion else "")
    if key in memo:
        return memo[key]
    memo[key] = HloTotals()        # break cycles defensively
    comp = comps.get(name)
    if comp is None:
        return memo[key]
    tot = HloTotals()
    for ins in comp.instrs:
        if ins.op == "dot":
            tot.flops += _dot_flops(ins, comp)
        base = ins.op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not ins.op.endswith("-done"):
            b = _shape_bytes(ins.shape)
            # XLA:CPU promotes bf16 reductions to f32 ("..._promoted"
            # to_apply computations); TPU runs them in bf16 — halve.
            if "_promoted" in ins.rest:
                b //= 2
            tot.coll_bytes[base] += b
        if not in_fusion and ins.op not in (
                "parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "while", "call", "conditional"):
            # materialized traffic: output + operand reads.
            if ins.op == "dynamic-update-slice":
                # in-place: only the update slice moves (operand 1)
                ops = re.findall(r"(%[\w.\-]+)", ins.rest)
                if len(ops) >= 2:
                    s = comp.shapes.get(ops[1])
                    if s:
                        tot.hbm_bytes += 2 * _shape_bytes(s)
            elif ins.op == "dynamic-slice":
                tot.hbm_bytes += 2 * _shape_bytes(ins.shape)
            elif ins.op == "fusion":
                tot.hbm_bytes += _fusion_hbm(ins, comp, comps)
            else:
                tot.hbm_bytes += _shape_bytes(ins.shape)
                for opname in re.findall(r"(%[\w.\-]+)", ins.rest):
                    s = comp.shapes.get(opname)
                    if s:
                        tot.hbm_bytes += _shape_bytes(s)
        if ins.op == "while":
            body = _CALL_RE.search(ins.rest)
            cond = _COND_RE.search(ins.rest)
            trips = 1
            if cond and cond.group(1) in comps:
                trips = _trip_count(comps[cond.group(1)])
            if body:
                sub = _analyze_comp(body.group(1), comps, memo, in_fusion)
                tot.add(sub, trips)
        elif ins.op in ("fusion",):
            call = _CALL_RE.search(ins.rest)
            if call:
                sub = _analyze_comp(call.group(1), comps, memo,
                                    in_fusion=True)
                tot.add(sub, 1.0)
        elif ins.op in ("call", "conditional", "async-start"):
            for call in _CALL_RE.findall(ins.rest):
                sub = _analyze_comp(call, comps, memo, in_fusion)
                tot.add(sub, 1.0)
    memo[key] = tot
    return tot


def analyze_hlo(text: str) -> HloTotals:
    comps = parse_computations(text)
    entry = comps.get("__ENTRY__")
    if entry is None:
        # fall back: last computation
        entry = list(comps.values())[-1]
    return _analyze_comp(entry.name, comps, {})


# ---------------------------------------------------------------------------
# Per-op attribution — the "profile" for the §Perf hypothesis loop.
# ---------------------------------------------------------------------------
def _collect_contribs(name: str, comps: Dict[str, Computation],
                      out: Dict[Tuple[str, str], List[float]],
                      mult: float, in_fusion: bool,
                      seen: Optional[set] = None) -> None:
    comp = comps.get(name)
    if comp is None:
        return
    seen = seen or set()
    if name in seen:
        return
    for ins in comp.instrs:
        flops = _dot_flops(ins, comp) if ins.op == "dot" else 0.0
        hbm = 0.0
        coll = 0.0
        base = ins.op.replace("-start", "").replace("-done", "")
        if base in _COLLECTIVES and not ins.op.endswith("-done"):
            b = _shape_bytes(ins.shape)
            if "_promoted" in ins.rest:
                b //= 2
            coll = b
        if not in_fusion and ins.op not in (
                "parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "while", "call", "conditional"):
            if ins.op == "dynamic-update-slice":
                ops = re.findall(r"(%[\w.\-]+)", ins.rest)
                if len(ops) >= 2:
                    s = comp.shapes.get(ops[1])
                    if s:
                        hbm = 2 * _shape_bytes(s)
            elif ins.op == "dynamic-slice":
                hbm = 2 * _shape_bytes(ins.shape)
            elif ins.op == "fusion":
                hbm = _fusion_hbm(ins, comp, comps)
            else:
                hbm = _shape_bytes(ins.shape)
                for opname in re.findall(r"(%[\w.\-]+)", ins.rest):
                    s = comp.shapes.get(opname)
                    if s:
                        hbm += _shape_bytes(s)
        if flops or hbm or coll:
            key = (ins.op, ins.shape if len(ins.shape) < 90
                   else ins.shape[:87] + "...")
            acc = out.setdefault(key, [0.0, 0.0, 0.0, 0.0])
            acc[0] += flops * mult
            acc[1] += hbm * mult
            acc[2] += coll * mult
            acc[3] += mult
        if ins.op == "while":
            body = _CALL_RE.search(ins.rest)
            cond = _COND_RE.search(ins.rest)
            trips = 1
            if cond and cond.group(1) in comps:
                trips = _trip_count(comps[cond.group(1)])
            if body:
                _collect_contribs(body.group(1), comps, out, mult * trips,
                                  in_fusion, seen | {name})
        elif ins.op == "fusion":
            call = _CALL_RE.search(ins.rest)
            if call:
                _collect_contribs(call.group(1), comps, out, mult, True,
                                  seen | {name})
        elif ins.op in ("call", "conditional", "async-start"):
            for call in _CALL_RE.findall(ins.rest):
                _collect_contribs(call, comps, out, mult, in_fusion,
                                  seen | {name})


def top_contributors(text: str, k: int = 25, by: str = "hbm") -> List[dict]:
    """Rank (op, shape) sites by hbm bytes / flops / collective bytes,
    with while-loop trip multipliers applied. `by`: hbm|flops|coll."""
    comps = parse_computations(text)
    entry = comps.get("__ENTRY__")
    if entry is None:
        entry = list(comps.values())[-1]
    out: Dict[Tuple[str, str], List[float]] = {}
    _collect_contribs(entry.name, comps, out, 1.0, False)
    idx = {"flops": 0, "hbm": 1, "coll": 2}[by]
    rows = [{"op": op, "shape": shape, "flops": v[0], "hbm_bytes": v[1],
             "coll_bytes": v[2], "count": v[3]}
            for (op, shape), v in out.items()]
    rows.sort(key=lambda r: -[r["flops"], r["hbm_bytes"],
                              r["coll_bytes"]][idx])
    return rows[:k]
