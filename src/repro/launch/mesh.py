"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init; smoke tests and
benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         dp: int = 16, tp: int = 16):
    """Single pod: (dp, tp) = 256 chips, axes (data, model); default
    (16, 16). Multi-pod: (2, dp, tp) = 512 chips, (pod, data, model).
    dp*tp must equal 256 (one v5e pod). Non-default splits (e.g. 8x32)
    are §Perf variants — see EXPERIMENTS.md iteration L4."""
    assert dp * tp == 256, (dp, tp)
    shape = (2, dp, tp) if multi_pod else (dp, tp)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_demo_mesh(n_devices: int | None = None, model_axis: int = 1):
    """CPU demo mesh over host devices: (n, model_axis)."""
    devs = jax.devices()
    n = n_devices or len(devs)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"),
                         devices=devs[:n])
