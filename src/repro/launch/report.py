"""Assemble EXPERIMENTS.md §Dry-run and §Roofline tables from the
results/dryrun JSON records.

  PYTHONPATH=src python -m repro.launch.report [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(dir_: str) -> List[dict]:
    recs = []
    for fn in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if abs(x) >= div:
            return f"{x / div:.1f}{unit}"
    return f"{x:.0f}B"


def fmt_t(x) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def dryrun_table(recs: List[dict], mesh: str) -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | compile | args/dev | temp/dev | "
           "collectives (ag/ar/rs/a2a/cp) |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        c = r["roofline"]["collective_by_kind"]
        coll = "/".join(fmt_b(c.get(k, 0)) for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compile_s']:.1f}s "
            f"| {fmt_b(r['memory']['argument_bytes'])} "
            f"| {fmt_b(r['memory']['temp_bytes'])} "
            f"| {coll} |")
    return "\n".join(out)


def roofline_table(recs: List[dict], mesh: str = "16x16") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    out = ["| arch | shape | t_compute | t_memory | t_collective | "
           "bottleneck | useful FLOPs ratio | what would move it |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        note = _fixit_note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} "
            f"| {fmt_t(ro['t_collective_s'])} | **{ro['bottleneck']}** "
            f"| {ratio:.2f} | {note} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_t(ro['t_compute_s'])} | {fmt_t(ro['t_memory_s'])} "
            f"| {fmt_t(ro['t_collective_s'])} | **{ro['bottleneck']}** "
            f"| - | {note} |")
    return "\n".join(out)


def _fixit_note(r: dict) -> str:
    ro = r["roofline"]
    b = ro["bottleneck"]
    kind = r["kind"]
    if b == "memory":
        if kind == "decode":
            return "quantize KV cache (int8) / widen batch per chip"
        return "fewer fp32 intermediates; larger attn chunk; offload"
    if b == "collective":
        return "seq-sharded (Megatron-SP) activations; overlap via async"
    return "MXU-aligned tiles; larger per-device batch"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.dir)
    print("## §Dry-run (single pod 16x16 = 256 chips)\n")
    print(dryrun_table(recs, "16x16"))
    print("\n## §Dry-run (multi-pod 2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "2x16x16"))
    print("\n## §Roofline (single pod, per device)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
