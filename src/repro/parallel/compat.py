"""Version compatibility for the jax parallelism API.

`shard_map` graduated from `jax.experimental.shard_map` to top-level
`jax.shard_map` (jax >= 0.6); this repo supports both so the executor
and ring-attention tests run on whichever the container ships.
"""
from __future__ import annotations

import jax


def _resolve_shard_map():
    try:
        return jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
        return shard_map


shard_map = _resolve_shard_map()


def axis_size(axis_name) -> int:
    """`jax.lax.axis_size` (jax >= 0.5) with a fallback for older jax:
    psum of the constant 1 over a named axis is folded to the axis size
    without touching devices, so it stays a Python int for ring loops."""
    try:
        return jax.lax.axis_size(axis_name)
    except AttributeError:
        return int(jax.lax.psum(1, axis_name))


__all__ = ["axis_size", "shard_map"]

