"""Activation sharding constraints (GSPMD propagation pinning).

Without hints, GSPMD occasionally resolves ambiguous einsum shardings by
replicating the batch dimension (observed: the SSD per-head map pulled a
global-batch all-gather into every layer). The fix is the MaxText-style
pattern: `with_sharding_constraint` at block boundaries.

The model code stays mesh-agnostic: it calls `constrain(x, kind)`, which
is a no-op unless a launcher installed a constrainer via
`activation_constraints(mesh, daxes)`.

Kinds: "hidden" [B,S,D] — batch over data axes, rest replicated;
       "ffn"    [B,S,F] — additionally F over model (tensor parallel);
       "logits" [B,S,V] — V over model.
"""
from __future__ import annotations

import contextlib
from typing import Callable, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_CONSTRAINER: Optional[Callable] = None


def constrain(x, kind: str = "hidden"):
    if _CONSTRAINER is None:
        return x
    return _CONSTRAINER(x, kind)


@contextlib.contextmanager
def activation_constraints(mesh, daxes: Tuple[str, ...],
                           model_axis: str = "model",
                           batch_sharded: bool = True,
                           sp: bool = False):
    """Install block-boundary constraints for the given mesh.

    `sp=True` enables Megatron-SP-style SEQUENCE sharding of the
    residual stream over the model axis: GSPMD then lowers each TP
    partial-sum boundary as reduce-scatter(+all-gather before the next
    sharded matmul) instead of a full [B,S,D] all-reduce, and the saved
    per-layer activations shrink by the TP width. This is a beyond-paper
    optimization recorded in EXPERIMENTS.md §Perf.
    """
    global _CONSTRAINER
    b = daxes if batch_sharded else None
    seq_ax = model_axis if sp else None

    def fn(x, kind):
        if x.ndim < 2:
            return x
        lead = (None,) * (x.ndim - 3) if x.ndim > 3 else ()
        if kind == "hidden":
            spec = (P(*lead, b, seq_ax, None) if x.ndim >= 3
                    else P(b, None))
        elif kind in ("ffn", "logits"):
            spec = (P(*lead, b, None, model_axis) if x.ndim >= 3
                    else P(b, model_axis))
        elif kind == "prehead":
            # Re-gather the sequence axis BEFORE the unembed matmul.
            # Under SP the residual is S-sharded over `model` while the
            # logits are V-sharded over `model`; if the S→V re-shard
            # happens after the matmul, GSPMD resolves the backward
            # same-axis conflict by all-gathering the [B,S,V] dlogits
            # (34 GB/device on pixtral) instead of the [B,S,D] hidden
            # (1.3 GB) — §Perf iteration P4.
            spec = (P(*lead, b, None, None) if x.ndim >= 3
                    else P(b, None))
        else:
            return x
        # skip if dims don't divide
        sizes = {a: mesh.shape[a] for a in mesh.axis_names}
        for dim, ax in zip(x.shape[x.ndim - len(tuple(spec)):],
                           tuple(spec)):
            axes = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            n = 1
            for a in axes:
                n *= sizes[a]
            if dim % n:
                return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    prev = _CONSTRAINER
    _CONSTRAINER = fn
    try:
        yield
    finally:
        _CONSTRAINER = prev
