"""Ring-style Context Parallelism — the paper's chosen CP substrate.

Key property DHP relies on (§4.1): the ring works for ANY positive
integer degree d, unlike Ulysses-style SP whose all-to-all requires the
degree to divide the head count. On TPU the ring is `jax.lax.ppermute`
over the `cp` mesh axis (neighbour hops on the ICI torus); each hop's
compute is a partial flash-attention with online-softmax accumulators
carried across hops, so communication of hop h+1 overlaps the compute of
hop h (the overlap credit of Eq. 10 — XLA's latency-hiding scheduler
performs the overlap since each hop's ppermute is independent of that
hop's FLOPs).

Mask generality: positions travel WITH the KV shards, so any sequence
layout works. `make_positions(..., striped=True)` gives the Striped
Attention layout (Brandon et al., cited by the paper) that balances the
causal-mask load across ranks; contiguous is the paper-faithful default.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import compat

NEG_INF = -1e30


def make_positions(seq_len: int, degree: int, rank: int,
                   striped: bool = False) -> jnp.ndarray:
    """Global token positions owned by `rank` (local order)."""
    per = seq_len // degree
    if striped:
        return jnp.arange(per) * degree + rank
    return rank * per + jnp.arange(per)


def shard_sequence(x: jnp.ndarray, degree: int, rank: int, axis: int = 1,
                   striped: bool = False) -> jnp.ndarray:
    """Slice the tokens a rank owns (host-side data dispatch helper)."""
    per = x.shape[axis] // degree
    if striped:
        idx = jnp.arange(per) * degree + rank
        return jnp.take(x, idx, axis=axis)
    return jax.lax.slice_in_dim(x, rank * per, (rank + 1) * per, axis=axis)


def _partial_update(carry, q, k, v, q_pos, k_pos, mode: str,
                    window: Optional[int], q_seg=None, k_seg=None,
                    q_span=None, k_span=None):
    """One online-softmax accumulation step. q:[B,S,Hkv,G,D] fp32-scaled,
    k/v:[B,T,Hkv,D]. carry = (m, l, acc). `q_seg`/`k_seg` ([B,S]/[B,T]
    int32, -1 = padding) restrict attention to same-segment pairs —
    the packed-varlen mode; `q_span`/`k_span` (-1 = causal) add the
    mixed modality mask (same-id bidirectional blocks attend forward);
    k_seg/k_span arrived with this hop's KV shard."""
    m, l, acc = carry
    s = jnp.einsum("bskgd,btkd->bskgt", q, k.astype(jnp.float32))
    mask = k_pos[:, None, :] <= q_pos[:, :, None]  # [B,S,T]
    if mode == "full":
        mask = jnp.ones_like(mask)
    elif mode == "sliding":
        mask &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if q_span is not None and mode != "full":
        mask |= (q_span[:, :, None] >= 0) \
            & (q_span[:, :, None] == k_span[:, None, :])
    if q_seg is not None:
        mask &= (q_seg[:, :, None] == k_seg[:, None, :]) \
            & (q_seg >= 0)[:, :, None]
    bias = jnp.where(mask, 0.0, NEG_INF)
    s = s + bias[:, :, None, None, :]
    m_new = jnp.maximum(m, s.max(axis=-1))
    corr = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * corr + p.sum(axis=-1)
    acc = acc * corr[..., None] + jnp.einsum(
        "bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    return m_new, l, acc


def ring_attention(q, k, v, q_pos, *, axis_name: str,
                   mode: str = "causal", window: Optional[int] = None,
                   q_seg=None, q_span=None) -> jax.Array:
    """Executed INSIDE shard_map. q:[B,S_loc,H,D], k/v:[B,S_loc,Hkv,D],
    q_pos:[B,S_loc] global positions of the local shard.

    Any integer ring size is legal — jax.lax.ppermute has no
    power-of-two or head-divisibility constraint (the paper's core
    flexibility argument, §4.1).

    `q_seg` ([B,S_loc] int32, -1 = padding) turns on packed-varlen
    masking: each hop's KV shard travels WITH its position table AND its
    segment table, so attention stays block-diagonal over segments no
    matter which rank currently holds the shard. Positions are
    per-segment (reset at each boundary); the causal comparison is only
    consulted for same-segment pairs, where it is exact.

    `q_span` ([B,S_loc] int32, -1 = causal) is the modality table of
    the local shard: same-id tokens form one bidirectional block
    (vision frame / audio window) that attends FORWARD within itself.
    Like segments and positions, the table rides every ppermute hop, so
    a block sharded across ranks stays bidirectional end to end.
    """
    d = compat.axis_size(axis_name)
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = (q.reshape(B, S, Hkv, G, Dh) / math.sqrt(Dh)).astype(jnp.float32)

    m = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, S, Hkv, G), jnp.float32)
    acc = jnp.zeros((B, S, Hkv, G, Dh), jnp.float32)
    carry = (m, l, acc)

    k_cur, v_cur, kpos_cur = k, v, q_pos
    kseg_cur = q_seg
    kspan_cur = q_span
    perm = [(i, (i - 1) % d) for i in range(d)]
    for hop in range(d):
        carry = _partial_update(carry, qg, k_cur, v_cur, q_pos, kpos_cur,
                                mode, window, q_seg=q_seg,
                                k_seg=kseg_cur, q_span=q_span,
                                k_span=kspan_cur)
        if hop != d - 1:
            # the hop carries exactly the tables in use: positions
            # always, the segment and modality tables when present
            extras = (() if q_seg is None else (kseg_cur,)) \
                + (() if q_span is None else (kspan_cur,))
            moved = jax.lax.ppermute((k_cur, v_cur, kpos_cur) + extras,
                                     axis_name, perm)
            k_cur, v_cur, kpos_cur = moved[:3]
            if q_seg is not None:
                kseg_cur = moved[3]
            if q_span is not None:
                kspan_cur = moved[-1]
    m, l, acc = carry
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def ring_decode_attention(q1, k_cache, v_cache, local_valid, *,
                          axis_name: str) -> jax.Array:
    """Decode with the KV cache sharded along sequence over `axis_name`
    (CP serving): each rank computes partial (max, sum, acc) over its
    cache shard; a tree psum combines — distributed softmax, one round.

    q1:[B,1,H,D] (replicated across the cp axis), caches [B,T_loc,Hkv,D],
    local_valid:[B] live entries of the local shard.
    """
    B, _, H, Dh = q1.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = (q1.reshape(B, 1, Hkv, G, Dh) / math.sqrt(Dh)).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg,
                   k_cache.astype(jnp.float32))
    live = jnp.arange(T)[None, :] < local_valid[:, None]
    s = jnp.where(live[:, None, None, None, :], s, NEG_INF)
    m_loc = s.max(axis=-1)
    m = jax.lax.pmax(m_loc, axis_name)
    p = jnp.exp(s - m[..., None])
    l = jax.lax.psum(p.sum(axis=-1), axis_name)
    acc = jnp.einsum("bskgt,btkd->bskgd", p,
                     v_cache.astype(jnp.float32))
    acc = jax.lax.psum(acc, axis_name)
    o = acc / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, 1, H, Dh).astype(q1.dtype)
