"""Per-architecture PartitionSpec rules for the (pod, data, model) mesh.

Conventions (MaxText-style):
  * `model` axis = tensor parallelism (the paper's static TP, §4.1) —
    shards attention heads, FFN hidden, MoE experts, vocab.
  * `data` axis = data parallelism; with `fsdp=True` parameters are also
    sharded over `data` on a non-model dimension (ZeRO-3, matching the
    paper's memory model M_ms = const per rank).
  * `pod` axis (multi-pod mesh) joins `data` for batch / FSDP sharding —
    cross-pod traffic is then gradient all-reduce + parameter all-gather,
    the DCI-friendly pattern.

PartitionSpecs are assigned by parameter-tree path; stacked layer params
get a leading None for the scan [L] axis automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import InputShape, ModelConfig

DP = "data"
TP = "model"
POD = "pod"


def data_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in (POD, DP) if a in mesh.axis_names)


def _rule(path: Tuple[str, ...], fsdp_axis) -> P:
    """Map a parameter path (joined names) to a spec, layer-axis excluded.

    FSDP placement rule: the `data` axes shard only NON-CONTRACTING
    dimensions. Sharding a weight's contracting dim over `data` makes
    GSPMD emit a full [B,S,D] activation all-reduce over the data axis
    per matmul (observed: 268 MB fp32 per layer on chatglm3); sharding
    the output dim instead yields the ZeRO-3 pattern — a small weight
    all-gather that XLA hoists/overlaps. See EXPERIMENTS.md §Perf-1.
    """
    last = path[-1]
    d = fsdp_axis  # None or tuple of data axes
    dt = tuple(d) if isinstance(d, (tuple, list)) else (
        (d,) if d else ())
    tp_d = (TP,) + dt or None   # output dim sharded by TP then fsdp

    # --- attention ---
    if last in ("wq", "wk", "wv"):      # [D_in, D_out] contract D_in
        return P(None, tp_d)
    if last == "wo":                    # [H*hd, D] contract H*hd
        return P(TP, d)
    # --- mlp ---
    if last in ("up", "gate") and "moe" not in path:
        return P(None, tp_d)            # [D, F] contract D
    if last == "down" and "moe" not in path:
        return P(TP, d)                 # [F, D] contract F
    # --- moe (experts stacked [E, ...]) -> expert parallelism over TP ---
    if "moe" in path:
        if last == "router":
            return P(None, None)
        if last in ("gate", "up"):      # [E, D, F] contract D
            return P(TP, None, dt or None)
        if last == "down":              # [E, F, D] contract F
            return P(TP, None, dt or None)
    # --- ssm ---
    if "ssm" in path:
        if last == "in_proj":           # [D, X] contract D
            return P(None, tp_d)
        if last == "out_proj":          # [W, D] contract W
            return P(TP, d)
        if last == "conv":              # [W, C] elementwise on C
            return P(None, TP)
        return P(*([None] * 1))
    # --- rglru ---
    if "rec" in path:
        if last in ("in_gate", "in_rec"):
            return P(None, dt or None)  # [D, W] contract D
        if last == "out":
            return P(None, d)           # [W, D] contract W (replicated)
        if last in ("w_a", "w_x"):      # [nb, Wb, Wb] block-diagonal
            return P(None, None, None)
        if last == "conv":
            return P(None, None)
        return P(None)
    # --- embeddings / head / connector ---
    if last == "embed":                 # [V, D] gather rows
        return P(tp_d, None)
    if last == "head":                  # [D, V] contract D
        return P(None, tp_d)
    if last == "connector":
        return P(None, TP)
    # --- norms & 1-D leaves ---
    return None  # resolved per-leaf rank below


def param_specs(params: Any, cfg: ModelConfig, *, fsdp: bool = True,
                mesh=None) -> Any:
    """Pytree of PartitionSpec matching `params`."""
    daxes = data_axes(mesh) if mesh is not None else (DP,)
    fsdp_axis = daxes if fsdp else None
    stacked_roots = ("layers", "units", "enc_layers", "dec_layers")

    def spec_for(path, leaf):
        names = tuple(
            p.key if hasattr(p, "key") else str(p) for p in path)
        stacked = names[0] in stacked_roots
        core = _rule(names, fsdp_axis)
        rank = leaf.ndim
        if core is None:
            core = P(*([None] * (rank - (1 if stacked else 0))))
        core_t = tuple(core)
        # pad/truncate to leaf rank (leaving the [L] axis unsharded)
        want = rank - (1 if stacked else 0)
        core_t = tuple(core_t[:want]) + (None,) * max(0, want - len(core_t))
        full = ((None,) if stacked else ()) + core_t
        # progressively drop trailing axes that do not divide the dim
        fixed = []
        for dim, ax in zip(leaf.shape, full):
            if ax is None or mesh is None:
                fixed.append(ax)
                continue
            axes = list(ax) if isinstance(ax, tuple) else [ax]
            while axes:
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                if dim % size == 0:
                    break
                axes.pop()          # drop the least-important (fsdp) axis
            fixed.append(tuple(axes) if len(axes) > 1
                         else (axes[0] if axes else None))
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, P]:
    """Input shardings for train/prefill batches."""
    daxes = data_axes(mesh)
    bs = daxes if shape.global_batch > 1 else None
    specs: Dict[str, P] = {
        "tokens": P(bs, None),
        "labels": P(bs, None),
    }
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(bs, None, None)
        specs["patch_pos"] = P(bs, None)
    if cfg.family == "audio":
        specs["frames"] = P(bs, None, None)
    return specs


def cache_specs(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, P]:
    """Decode-cache shardings.

    decode_32k (B=128): batch over data axes, kv-heads over model when
    divisible, else sequence over model.
    long_500k (B=1): batch unshardable -> shard cache SEQUENCE over the
    data axes (context-parallel serving — DHP's CP applied to decode)
    and heads over model.
    """
    daxes = data_axes(mesh)
    batch_shardable = shape.global_batch > 1
    b_ax = daxes if batch_shardable else None
    seq_data = None if batch_shardable else daxes

    # heads over `model` when divisible, else the cache SEQUENCE over
    # `model` (distributed-softmax decode — CP applied to serving).
    tp_heads = mesh is not None and cfg.kv_heads \
        and cfg.kv_heads % mesh.shape[TP] == 0
    head_ax = TP if tp_heads else None
    seq_tp = None if tp_heads else TP
    # combine data-seq and model-seq sharding axes
    seq_axes = []
    if seq_data:
        seq_axes.extend(seq_data if isinstance(seq_data, tuple)
                        else (seq_data,))
    if seq_tp:
        seq_axes.append(seq_tp)
    seq_spec = tuple(seq_axes) if seq_axes else None

    kv = P(None, b_ax, seq_spec, head_ax, None)
    specs: Dict[str, Any] = {"pos": P()}
    if cfg.family in ("dense", "moe", "vlm"):
        specs.update(k=kv, v=kv)
    elif cfg.family == "ssm":
        specs.update(
            h=P(None, b_ax, TP, None, None),
            conv_buf=P(None, b_ax, None, TP),
        )
    elif cfg.family == "hybrid":
        specs.update(
            rec_h=P(None, None, b_ax, None),
            rec_conv=P(None, None, b_ax, None, None),
            k=P(None, None, b_ax, seq_spec, head_ax, None),
            v=P(None, None, b_ax, seq_spec, head_ax, None),
            tail_h=P(None, b_ax, None),
            tail_conv=P(None, b_ax, None, None),
        )
    elif cfg.family == "audio":
        specs.update(k=kv, v=kv,
                     cross_k=P(None, b_ax, None, head_ax, None),
                     cross_v=P(None, b_ax, None, head_ax, None))
    return specs
