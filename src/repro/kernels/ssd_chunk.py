"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk step.

The chunked SSD algorithm (arXiv:2405.21060, models/ssm.py) evaluates
the recurrence inside each length-`c` chunk in its dual quadratic form.
The hot spot is per (batch·head, chunk):

    cum      = cumsum(dt·A)                       [c]
    L[i,j]   = exp(cum_i − cum_j) · 1[i ≥ j]      [c,c]   (decay mask)
    scores   = (C Bᵀ) ∘ L ∘ dt_j                  [c,c]
    y_intra  = scores · x                         [c,P]
    states   = (B ∘ dt ∘ exp(cum_c − cum))ᵀ · x   [N,P]   (chunk summary)

On GPU this is where Mamba-2 fuses into a single kernel so the [c,c]
matrices never hit HBM; the TPU-native adaptation is the same fusion
with MXU-shaped tiles: one grid cell = one (bh, chunk), all [c,N]/[c,P]
blocks resident in VMEM (c = 128–256, N = 128, P = 64–128 ⇒ ≤ 0.6 MB of
fp32 per cell), the two matmuls hit the 128×128 systolic array, and only
y_intra / states / cum are written back. The O(S/c) inter-chunk state
scan stays outside (it is tiny: [N,P] per head) — see
`ops.ssd_chunk_scan` for the composed op.

Validated against `ref.ssd_chunk_ref` in interpret mode (CPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, b_ref, x_ref, da_ref, dt_ref,
            y_ref, st_ref, cum_ref):
    C = c_ref[0].astype(jnp.float32)       # [c, N]
    B = b_ref[0].astype(jnp.float32)       # [c, N]
    x = x_ref[0].astype(jnp.float32)       # [c, P]
    da = da_ref[0].astype(jnp.float32)     # [c]
    dt = dt_ref[0].astype(jnp.float32)     # [c]
    c = C.shape[0]

    cum = jnp.cumsum(da)                                    # [c]
    diff = cum[:, None] - cum[None, :]                      # [c,c]
    i = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    L = jnp.where(i >= j, jnp.exp(diff), 0.0)               # decay mask
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = cb * L * dt[None, :]                           # [c,c]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    decay_end = jnp.exp(cum[-1] - cum) * dt                 # [c]
    st = jax.lax.dot_general(B * decay_end[:, None], x,
                             (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [N,P]
    y_ref[0] = y.astype(y_ref.dtype)
    st_ref[0] = st.astype(st_ref.dtype)
    cum_ref[0] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(C, B, x, da, dt, *, interpret: bool = True):
    """Intra-chunk SSD for a batch of independent chunks.

    C, B: [G, c, N]; x: [G, c, P]; da, dt: [G, c]
      (G = batch · heads · n_chunks flattened; da = dt·A)
    Returns (y_intra [G,c,P], states [G,N,P], cum [G,c]) in fp32.
    """
    G, c, N = C.shape
    P = x.shape[-1]
    return pl.pallas_call(
        _kernel,
        grid=(G,),
        in_specs=[
            pl.BlockSpec((1, c, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, c, N), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, c, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, c), lambda g: (g, 0)),
            pl.BlockSpec((1, c), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, N, P), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, c), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, c, P), jnp.float32),
            jax.ShapeDtypeStruct((G, N, P), jnp.float32),
            jax.ShapeDtypeStruct((G, c), jnp.float32),
        ],
        interpret=interpret,
    )(C, B, x, da, dt)
