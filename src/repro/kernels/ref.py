"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, mode: str = "causal",
                        window: Optional[int] = None,
                        q_offset: int = 0) -> jax.Array:
    """q: [BH, Sq, D], k/v: [BH, Sk, D] (heads pre-flattened, KV already
    expanded to full heads). fp32 softmax, same-dtype output as q."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    if mode == "full":
        m = jnp.ones((Sq, Sk), bool)
    else:
        m = kpos[None, :] <= qpos[:, None]
        if mode == "sliding":
            assert window is not None
            m &= kpos[None, :] > (qpos[:, None] - window)
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_attention_packed_ref(q, k, v, segment_ids, *,
                               mode: str = "causal",
                               window: Optional[int] = None,
                               span_ids=None) -> jax.Array:
    """Block-diagonal (packed varlen) oracle. q/k/v: [BH, S, D] packed
    token buffers; segment_ids: [S] int32, -1 marks tail padding.

    Attention is masked to same-segment pairs; within a segment the
    causal/sliding structure uses packed indices directly (positions are
    monotone inside a segment, so `kpos <= qpos` in packed coordinates IS
    per-segment causality). `span_ids` ([S] int32, -1 = causal) adds the
    mixed modality mask: same-id tokens (one vision frame / audio
    window) attend bidirectionally within their block, overriding the
    positional constraint but never the segment one. Rows with no
    attendable key (padding) emit exact zeros — matching the Pallas
    kernel's skipped-tile semantics.
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    seg = jnp.asarray(segment_ids, jnp.int32)
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    same = (seg[:Sq, None] == seg[None, :Sk]) & (seg[:Sq, None] >= 0)
    if mode == "full":
        m = same
    else:
        ok = kpos[None, :] <= qpos[:, None]
        if mode == "sliding":
            assert window is not None
            ok &= kpos[None, :] > (qpos[:, None] - window)
        if span_ids is not None:
            sp = jnp.asarray(span_ids, jnp.int32)
            ok |= (sp[:Sq, None] >= 0) & (sp[:Sq, None] == sp[None, :Sk])
        m = same & ok
    s = jnp.where(m[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    any_valid = m.any(axis=-1)                          # [Sq]
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    o = jnp.where(any_valid[None, :, None], o, 0.0)
    return o.astype(q.dtype)


def ssd_chunk_ref(C, B, x, da, dt):
    """Oracle for the SSD intra-chunk step (ssd_chunk.py).

    C, B: [G,c,N]; x: [G,c,P]; da, dt: [G,c] →
    (y_intra [G,c,P], states [G,N,P], cum [G,c]), fp32.
    """
    C = C.astype(jnp.float32)
    B = B.astype(jnp.float32)
    x = x.astype(jnp.float32)
    da = da.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    c = C.shape[1]
    cum = jnp.cumsum(da, axis=1)                           # [G,c]
    diff = cum[:, :, None] - cum[:, None, :]
    tril = jnp.tril(jnp.ones((c, c), bool))
    L = jnp.where(tril[None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("gin,gjn->gij", C, B) * L * dt[:, None, :]
    y = jnp.einsum("gij,gjp->gip", scores, x)
    decay_end = jnp.exp(cum[:, -1:] - cum) * dt            # [G,c]
    states = jnp.einsum("gjn,gj,gjp->gnp", B, decay_end, x)
    return y, states, cum


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential oracle for h_t = a_t * h_{t-1} + b_t. a,b: [B,S,W]."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a_t = jnp.swapaxes(a, 0, 1)
    b_t = jnp.swapaxes(b, 0, 1)
    h0 = jnp.zeros_like(a[:, 0])
    _, hs = jax.lax.scan(step, h0, (a_t, b_t))
    return jnp.swapaxes(hs, 0, 1)
