"""Pallas TPU kernel: blocked RG-LRU linear scan.

h_t = a_t * h_{t-1} + b_t, evaluated chunk-by-chunk: the grid's
sequential axis walks sequence chunks, a VMEM scratch carries the running
state across chunks, and within a chunk the recurrence closes via a small
log2(chunk) Hillis-Steele pass over VREG-resident tiles. The channel axis
is tiled to the 128-lane VPU width.

This is the TPU adaptation of Griffin's CUDA linear-scan kernel: instead
of warp shuffles, we exploit the VPU's full-width elementwise throughput
and keep the carried state in VMEM scratch between grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, carry_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        carry_scr[...] = jnp.zeros_like(carry_scr)

    a = a_ref[0].astype(jnp.float32)          # [chunk, w]
    b = b_ref[0].astype(jnp.float32)

    # Hillis-Steele inclusive scan of the affine maps within the chunk
    step = 1
    while step < chunk:
        a_prev = jnp.concatenate(
            [jnp.ones((step, a.shape[1]), jnp.float32), a[:-step]], axis=0)
        b_prev = jnp.concatenate(
            [jnp.zeros((step, b.shape[1]), jnp.float32), b[:-step]], axis=0)
        b = a * b_prev + b
        a = a * a_prev
        step *= 2

    h0 = carry_scr[...]                        # [1, w] carried state
    h = a * h0 + b                             # close over previous chunks
    h_ref[0] = h.astype(h_ref.dtype)
    carry_scr[...] = h[-1:]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def rglru_scan_pallas(a, b, *, chunk: int = 128,
                      interpret: bool = True) -> jax.Array:
    """a, b: [B, S, W] -> h: [B, S, W] with h_t = a_t h_{t-1} + b_t."""
    B, S, W = a.shape
    pad = (-S) % chunk
    if pad:
        # identity padding: a=1, b=0 keeps the state unchanged
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    out = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, W), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, W), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, W), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S + pad, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:, :S]
