"""Pallas TPU flash-attention kernel.

The compute hot-spot DHP's cost model centres on (the a1*(1+eta)|s|^2
term of Eq. 8). TPU-native design, not a CUDA port:

  * grid = (batch*heads, num_q_blocks, num_kv_blocks); the LAST axis is
    sequential on TPU, so the online-softmax running state (m, l, acc)
    lives in VMEM scratch carried across kv iterations — the TPU analogue
    of a CUDA persistent-CTA loop.
  * BlockSpecs tile Q/K/V into (BLOCK_Q x HEAD_DIM) / (BLOCK_K x
    HEAD_DIM) VMEM windows; 128-multiples align with MXU systolic tiles
    and the (8,128) VREG lanes.
  * mask modes: causal / full / sliding(window) + a kv_offset so the
    SAME kernel computes each hop of ring attention (KV blocks arriving
    from a ppermute neighbour carry their global offset).
  * causal/sliding hops skip fully-masked KV blocks via pl.when —
    compute truly drops, unlike a masked dense matmul.
  * packed varlen mode (`flash_attention_packed_flat`): a whole atomic
    group concatenated into ONE token buffer with a segment-id table;
    attention is block-diagonal over segments and cross-segment /
    padding / future-causal KV tiles are skipped via pl.when. This is
    what collapses the executor's executable key space (see
    core/executor.py) — group shape no longer depends on how many
    sequences were packed, only on the padded packed bucket.
  * mixed modality mask: a span-id table rides next to the segment
    table; same-id tokens (one bidirectional vision frame / audio
    window) attend each other regardless of order inside their segment
    — the mask DHP's Eq. 8 eta factor costs (span ids -1 = causal).

Validated against ref.flash_attention_ref / ref.flash_attention_packed_ref
in interpret mode (CPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            mode: str, window: Optional[int], sm_scale: float,
            block_q: int, block_k: int, kv_offset: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile
    q_start = qi * block_q
    k_start = kv_offset + ki * block_k

    # block-level skip: entire KV tile masked out?
    if mode == "full":
        full_skip = False
    elif mode == "causal":
        # kv block strictly after the last q row -> skip
        full_skip = k_start > q_start + block_q - 1
    else:  # sliding
        full_skip = jnp.logical_or(
            k_start > q_start + block_q - 1,
            k_start + block_k - 1 <= q_start - window)

    @pl.when(jnp.logical_not(full_skip) if mode != "full" else True)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # [bq, d]
        k = k_ref[0].astype(jnp.float32)          # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # [bq, bk]

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = kpos < kv_offset + kv_len           # tail padding
        if mode != "full":
            mask &= kpos <= qpos
            if mode == "sliding":
                mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def _packed_kernel(q_ref, k_ref, v_ref, segq_ref, segk_ref, *refs,
                   mode: str, window: Optional[int], sm_scale: float,
                   block_q: int, block_k: int, kv_offset: int,
                   has_spans: bool):
    """Segment-aware (packed varlen) flash attention tile with the
    mixed modality mask.

    All sequences of a group live concatenated in ONE token buffer;
    attention is block-diagonal across segment boundaries. Inside a
    segment, packed indices are monotone in position, so the causal /
    sliding structure is expressed directly in packed coordinates; with
    `has_spans` (a STATIC flag — span-free callers get the exact
    pre-span kernel, no dummy tables or dead mask work) a span table
    (-1 = causal text/padding) additionally lets same-id tokens — one
    bidirectional vision frame / audio window — attend FORWARD within
    their block, the mixed mask of DHP Eq. 8. A KV tile with no
    attendable (q, k) pair is skipped via pl.when — the MXU work truly
    drops, it is not a masked dense matmul.
    """
    if has_spans:
        spanq_ref, spank_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kv_offset + ki * block_k
    seg_q = segq_ref[0]                                  # [bq] int32
    seg_k = segk_ref[0]                                  # [bk] int32
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
    # same segment; padding (seg < 0) never attends or is attended
    valid = (seg_q[:, None] == seg_k[None, :]) & (seg_q >= 0)[:, None]
    if mode != "full":
        ok = kpos <= qpos
        if mode == "sliding":
            ok &= kpos > qpos - window
        if has_spans:
            span_q = spanq_ref[0]                        # [bq] int32
            span_k = spank_ref[0]                        # [bk] int32
            ok |= (span_q >= 0)[:, None] \
                & (span_q[:, None] == span_k[None, :])
        valid &= ok
    # O(bq*bk) mask vs O(bq*bk*D) matmuls: deciding the skip costs 1/D
    # of the tile; fully-masked tiles (cross-segment, future-causal,
    # out-of-window, tail padding) skip both MXU passes.
    live = jnp.any(valid)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # rows of this tile with no valid key contribute nothing
        p = jnp.where(valid.any(axis=1)[:, None], p, 0.0)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "window", "block_q", "block_k", "kv_offset",
                     "interpret"))
def flash_attention_packed_flat(q, k, v, segment_ids, *,
                                mode: str = "causal",
                                window: Optional[int] = None,
                                kv_segment_ids=None,
                                span_ids=None,
                                kv_span_ids=None,
                                block_q: int = DEFAULT_BLOCK_Q,
                                block_k: int = DEFAULT_BLOCK_K,
                                kv_offset: int = 0,
                                interpret: bool = True) -> jax.Array:
    """Packed variable-length flash attention.

    q: [BH, Sq, D]; k/v: [BH, Sk, D]; segment_ids: [Sq] or [BH, Sq]
    int32, -1 for tail padding. `kv_segment_ids` defaults to
    `segment_ids` (self-attention); pass the neighbour's table for a
    ring hop together with its `kv_offset`. `span_ids`/`kv_span_ids`
    (same shapes, -1 = causal) mark bidirectional modality blocks —
    same-id tokens attend each other regardless of order, inside their
    segment; None means pure segment-causal masking.

    Rows whose segment never matches (tail padding) emit exact zeros.
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    kv_seg = segment_ids if kv_segment_ids is None else kv_segment_ids
    kv_span = span_ids if kv_span_ids is None else kv_span_ids
    has_spans = kv_span is not None or span_ids is not None
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v

    def _norm_seg(seg, length, pad, fill):
        if seg is None:
            return jnp.full((BH, length + pad), fill, jnp.int32)
        seg = jnp.asarray(seg, jnp.int32)
        if seg.ndim == 1:
            seg = jnp.broadcast_to(seg[None], (BH, length))
        return jnp.pad(seg, ((0, 0), (0, pad)), constant_values=fill)

    segq = _norm_seg(segment_ids, Sq, pad_q, -1)         # [BH, Sq+pad]
    segk = _norm_seg(kv_seg, Sk, pad_k, -2)              # [BH, Sk+pad]
    nq = (Sq + pad_q) // block_q
    nk = (Sk + pad_k) // block_k

    kernel = functools.partial(
        _packed_kernel, mode=mode, window=window,
        sm_scale=1.0 / math.sqrt(D), block_q=block_q, block_k=block_k,
        kv_offset=kv_offset, has_spans=has_spans)

    q_spec = pl.BlockSpec((1, block_q), lambda b, i, j: (b, i))
    k_spec = pl.BlockSpec((1, block_k), lambda b, i, j: (b, j))
    in_specs = [
        pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        q_spec, k_spec,
    ]
    inputs = [qp, kp, vp, segq, segk]
    if has_spans:
        # span tables only enter the kernel when a layout exists —
        # span-free callers keep the exact pre-span kernel program
        in_specs += [q_spec, k_spec]
        inputs += [_norm_seg(span_ids, Sq, pad_q, -1),
                   _norm_seg(kv_span, Sk, pad_k, -2)]

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(*inputs)
    return out[:, :Sq]


@functools.partial(
    jax.jit,
    static_argnames=("mode", "window", "block_q", "block_k", "kv_offset",
                     "interpret"))
def flash_attention_flat(q, k, v, *, mode: str = "causal",
                         window: Optional[int] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         kv_offset: int = 0,
                         interpret: bool = True) -> jax.Array:
    """q: [BH, Sq, D]; k/v: [BH, Sk, D] (KV pre-expanded to all heads).

    `interpret=True` runs the kernel body on CPU (this container);
    compile for real TPUs with interpret=False.
    """
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0))) if pad_k else v
    nq = (Sq + pad_q) // block_q
    nk = (Sk + pad_k) // block_k

    kernel = functools.partial(
        _kernel, mode=mode, window=window, sm_scale=1.0 / math.sqrt(D),
        block_q=block_q, block_k=block_k, kv_offset=kv_offset, kv_len=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pad_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
