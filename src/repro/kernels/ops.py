"""jit'd public wrappers around the Pallas kernels.

`flash_attention` takes the model-layer layout [B, S, H, D] with GQA
KV [B, S, Hkv, D], expands KV groups, flattens (batch, head) and
dispatches to the kernel (or the jnp reference when ref=True).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import (flash_attention_flat,
                              flash_attention_packed_flat)
from .ref import (flash_attention_packed_ref, flash_attention_ref,
                  ssd_chunk_ref)
from .rglru_scan import rglru_scan_pallas
from .ssd_chunk import ssd_chunk_pallas


def _expand_gqa(k: jax.Array, n_heads: int) -> jax.Array:
    B, S, Hkv, D = k.shape
    G = n_heads // Hkv
    return jnp.repeat(k, G, axis=2)


@partial(jax.jit,
         static_argnames=("mode", "window", "ref", "interpret", "block_q",
                          "block_k"))
def flash_attention(q, k, v, *, mode: str = "causal",
                    window: Optional[int] = None, ref: bool = False,
                    interpret: bool = True, block_q: int = 128,
                    block_k: int = 128) -> jax.Array:
    """q: [B,S,H,D]; k/v: [B,S,Hkv,D] -> [B,S,H,D]."""
    B, Sq, H, D = q.shape
    k = _expand_gqa(k, H)
    v = _expand_gqa(v, H)
    Sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    if ref:
        of = flash_attention_ref(qf, kf, vf, mode=mode, window=window)
    else:
        of = flash_attention_flat(qf, kf, vf, mode=mode, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)
    return of.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@partial(jax.jit,
         static_argnames=("mode", "window", "ref", "interpret", "block_q",
                          "block_k"))
def flash_attention_packed(q, k, v, segment_ids, *, mode: str = "causal",
                          window: Optional[int] = None,
                          span_ids=None, ref: bool = False,
                          interpret: bool = True, block_q: int = 128,
                          block_k: int = 128) -> jax.Array:
    """Packed varlen attention in model layout.

    q: [B,S,H,D]; k/v: [B,S,Hkv,D]; segment_ids: [B,S] or [S] int32
    (-1 = tail padding) -> [B,S,H,D]. Each batch row is an independent
    packed buffer; attention is block-diagonal over its segments.
    `span_ids` (same shape convention, -1 = causal) marks bidirectional
    modality blocks for the mixed mask.
    """
    B, Sq, H, D = q.shape
    k = _expand_gqa(k, H)
    v = _expand_gqa(v, H)
    Sk = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)

    def _norm(t):
        if t is None:
            return None
        t = jnp.asarray(t, jnp.int32)
        if t.ndim == 2:                     # [B,S] -> [B*H, S]
            t = jnp.repeat(t, H, axis=0)
        return t

    seg = _norm(segment_ids)
    span = _norm(span_ids)
    if ref:
        if seg.ndim == 1 and (span is None or span.ndim == 1):
            of = flash_attention_packed_ref(qf, kf, vf, seg, mode=mode,
                                            window=window, span_ids=span)
        else:
            seg2 = jnp.broadcast_to(seg, (B * H, Sk)) \
                if seg.ndim == 1 else seg
            if span is None:
                of = jax.vmap(
                    lambda qq, kk, vv, ss: flash_attention_packed_ref(
                        qq[None], kk[None], vv[None], ss, mode=mode,
                        window=window)[0])(qf, kf, vf, seg2)
            else:
                span2 = jnp.broadcast_to(span, (B * H, Sk)) \
                    if span.ndim == 1 else span
                of = jax.vmap(
                    lambda qq, kk, vv, ss, pp: flash_attention_packed_ref(
                        qq[None], kk[None], vv[None], ss, mode=mode,
                        window=window, span_ids=pp)[0])(
                    qf, kf, vf, seg2, span2)
    else:
        of = flash_attention_packed_flat(qf, kf, vf, seg, mode=mode,
                                         window=window, span_ids=span,
                                         block_q=block_q,
                                         block_k=block_k,
                                         interpret=interpret)
    return of.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("ref", "interpret"))
def ssd_chunk_scan(C, B, x, da, dt, *, ref: bool = False,
                   interpret: bool = True):
    """Full chunked-SSD output for independent sequences of chunks.

    C, B: [G, nc, c, N]; x: [G, nc, c, P]; da, dt: [G, nc, c]
      (G = batch·heads; nc chunks of length c per sequence).
    Returns y [G, nc, c, P] fp32 — intra-chunk term from the Pallas
    kernel (or jnp oracle with ref=True) + inter-chunk term from the
    O(nc) state scan, exactly the models/ssm.py decomposition.
    """
    G, nc, c, N = C.shape
    P = x.shape[-1]
    flat = lambda t: t.reshape((G * nc,) + t.shape[2:])   # noqa: E731
    fn = ssd_chunk_ref if ref else partial(ssd_chunk_pallas,
                                           interpret=interpret)
    y_intra, states, cum = fn(flat(C), flat(B), flat(x), flat(da),
                              flat(dt))
    y_intra = y_intra.reshape(G, nc, c, P)
    states = states.reshape(G, nc, N, P)
    cum = cum.reshape(G, nc, c)
    seg_end = cum[..., -1]                                 # [G,nc]

    def scan_fn(h, inp):
        st, dec = inp
        return h * jnp.exp(dec)[:, None, None] + st, h     # emit PREV
    _, h_prev = jax.lax.scan(
        scan_fn, jnp.zeros((G, N, P), jnp.float32),
        (states.transpose(1, 0, 2, 3), seg_end.transpose(1, 0)))
    h_prev = h_prev.transpose(1, 0, 2, 3)                  # [G,nc,N,P]
    y_inter = jnp.einsum("gcin,gcnp->gcip", C.astype(jnp.float32),
                         h_prev) * jnp.exp(cum)[..., None]
    return y_intra + y_inter


__all__ = ["flash_attention", "flash_attention_packed",
           "rglru_scan_pallas", "ssd_chunk_pallas", "ssd_chunk_scan"]
