"""Tracing — monotonic-clock spans exported as Chrome trace-event JSON.

The DHP pitch is "millisecond-class planning hidden behind execution",
which is exactly the kind of claim a scalar metric cannot settle: you
need to SEE the planner thread's solve sitting under the device step,
which stage of a slow schedule() ate the budget, and which rank's group
stretched a wave. `Tracer` records that timeline:

  * spans (`ph: "X"` complete events) + instants + counter tracks,
    timestamped off ONE `time.perf_counter()` epoch so host threads and
    simulated-rank tracks share a timebase;
  * one track per host thread (main loop, lookahead planner thread, …)
    under the "host" process, and one track per simulated rank under the
    "ranks" process — the per-rank timeline the straggler analytics in
    `obs/report.py` visualise;
  * a ring buffer (`capacity` events, oldest evicted first) so tracing a
    long run has bounded memory;
  * `to_json()` / `save()` emit the Chrome trace-event format — load the
    file at https://ui.perfetto.dev or chrome://tracing.

The module-global default tracer is a `NullTracer` whose every method is
a no-op (`get_tracer()` in a hot path costs one attribute read); callers
opt in per run via `set_tracer` or the `tracing(...)` context manager —
`Engine.train(trace=...)` and `ServingEngine.run(trace=...)` do this.

Everything here is stdlib-only: the obs package sits BELOW repro.core in
the import graph so any layer may instrument itself.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Optional

#: Chrome trace-event process ids: host python threads vs simulated ranks.
PID_HOST = 1
PID_RANKS = 2

_PROCESS_NAMES = {PID_HOST: "host", PID_RANKS: "ranks"}


class _NullSpan:
    """Reusable no-op context manager (no allocation per span)."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled tracer: every method is a true no-op."""

    enabled = False

    def span(self, name: str, cat: str = "host", *,
             args: Optional[dict] = None) -> _NullSpan:
        return _NULL_SPAN

    def complete(self, name: str, start_s: float, dur_s: float,
                 cat: str = "host", *, args: Optional[dict] = None,
                 pid: Optional[int] = None,
                 tid: Optional[int] = None) -> None:
        pass

    def rank_span(self, name: str, rank: int, start_s: float,
                  dur_s: float, *, args: Optional[dict] = None) -> None:
        pass

    def instant(self, name: str, cat: str = "host", *,
                args: Optional[dict] = None) -> None:
        pass

    def counter(self, name: str, values: Dict[str, float]) -> None:
        pass


NULL_TRACER = NullTracer()


class _Span:
    """Context manager recording one complete event on the current
    thread's track."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[dict]):
        self._tr = tracer
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tr.complete(self._name, self._t0, t1 - self._t0,
                          self._cat, args=self._args)
        return False


class Tracer:
    """Thread-safe ring-buffered trace recorder.

    All timestamps come from `time.perf_counter()` relative to the
    tracer's construction instant, exported in microseconds (the Chrome
    trace-event unit). Thread ids are assigned in registration order
    (tid 0 = first thread to emit — usually the main loop; the lookahead
    planner thread gets its own track automatically). Rank-track events
    (`rank_span`) land under a separate "ranks" process with tid = rank
    index.
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._t0 = time.perf_counter()
        #: deque(maxlen=...) IS the ring buffer: appends past capacity
        #: evict the OLDEST event, so the newest window always survives.
        self._events: "deque[dict]" = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._thread_ids: Dict[int, int] = {}
        self._track_names: Dict[tuple, str] = {}
        self.dropped = 0          # events evicted by the ring buffer

    # -- track bookkeeping ----------------------------------------------
    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._thread_ids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._thread_ids.setdefault(
                    ident, len(self._thread_ids))
                self._track_names.setdefault(
                    (PID_HOST, tid), threading.current_thread().name)
        return tid

    def _rank_tid(self, rank: int) -> int:
        key = (PID_RANKS, int(rank))
        if key not in self._track_names:
            with self._lock:
                self._track_names.setdefault(key, f"rank {int(rank)}")
        return int(rank)

    def _ts(self, t_s: float) -> float:
        return (t_s - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        self._events.append(ev)

    # -- emission --------------------------------------------------------
    def span(self, name: str, cat: str = "host", *,
             args: Optional[dict] = None) -> _Span:
        """Context manager: a complete event on the calling thread's
        track, timed from __enter__ to __exit__."""
        return _Span(self, name, cat, args)

    def complete(self, name: str, start_s: float, dur_s: float,
                 cat: str = "host", *, args: Optional[dict] = None,
                 pid: Optional[int] = None,
                 tid: Optional[int] = None) -> None:
        """A complete event with EXPLICIT perf_counter() times — for
        callers that already hold the timestamps (the scheduler's stage
        clocks, the executor's measured group seconds)."""
        ev = {"name": name, "cat": cat, "ph": "X",
              "ts": self._ts(start_s), "dur": max(dur_s, 0.0) * 1e6,
              "pid": PID_HOST if pid is None else pid,
              "tid": self._tid() if tid is None else tid}
        if args:
            ev["args"] = args
        self._push(ev)

    def rank_span(self, name: str, rank: int, start_s: float,
                  dur_s: float, *, args: Optional[dict] = None) -> None:
        """A complete event on simulated rank `rank`'s track."""
        self.complete(name, start_s, dur_s, "rank", args=args,
                      pid=PID_RANKS, tid=self._rank_tid(rank))

    def instant(self, name: str, cat: str = "host", *,
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
              "ts": self._ts(time.perf_counter()),
              "pid": PID_HOST, "tid": self._tid()}
        if args:
            ev["args"] = args
        self._push(ev)

    def counter(self, name: str, values: Dict[str, float]) -> None:
        """A counter-track sample (`ph: "C"`) — Perfetto renders these as
        stacked area charts (e.g. KV occupancy, queue depth)."""
        self._push({"name": name, "cat": "counter", "ph": "C",
                    "ts": self._ts(time.perf_counter()),
                    "pid": PID_HOST, "tid": 0,
                    "args": dict(values)})

    # -- export ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def to_json(self) -> dict:
        """The Chrome trace-event document. Metadata (process/thread
        names) lives outside the ring buffer so track labels survive
        eviction."""
        with self._lock:
            names = dict(self._track_names)
            events = list(self._events)
        meta = []
        for pid, pname in _PROCESS_NAMES.items():
            meta.append({"name": "process_name", "ph": "M", "pid": pid,
                         "tid": 0, "args": {"name": pname}})
        for (pid, tid), tname in sorted(names.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": tname}})
        return {"traceEvents": meta + events,
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f)


# -- schema validation --------------------------------------------------------
_REQUIRED = {"X": ("ts", "dur"), "i": ("ts",), "C": ("ts", "args"),
             "M": ("args",)}


def validate_trace(obj: Any) -> int:
    """Validate a Chrome trace-event document; returns the event count.

    Checks the invariants Perfetto/chrome://tracing rely on — top-level
    `traceEvents` list; every event carries `name`/`ph`/`pid`/`tid`;
    per-phase required fields (`ts`+`dur` for complete events, `ts` for
    instants/counters, `args` for metadata); numeric, non-negative
    times. Raises ValueError on the first violation. Used by the trace
    schema tests AND by the benchmark before publishing the CI trace
    artifact."""
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a dict with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} missing {field!r}: {ev}")
        ph = ev["ph"]
        if ph not in _REQUIRED:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if not isinstance(ev["pid"], int) or not isinstance(ev["tid"],
                                                            int):
            raise ValueError(f"event {i}: pid/tid must be ints: {ev}")
        for field in _REQUIRED[ph]:
            if field not in ev:
                raise ValueError(
                    f"event {i} (ph={ph}) missing {field!r}: {ev}")
        for field in ("ts", "dur"):
            if field in ev:
                v = ev[field]
                if not isinstance(v, (int, float)) or v < 0:
                    raise ValueError(
                        f"event {i}: {field} must be a non-negative "
                        f"number, got {v!r}")
    return len(events)


# -- the process-global default tracer ---------------------------------------
_tracer: Any = NULL_TRACER


def get_tracer():
    """The process-global tracer (NULL_TRACER unless a run opted in)."""
    return _tracer


def set_tracer(tracer) -> Any:
    """Install `tracer` as the global default (None -> NULL_TRACER)."""
    global _tracer
    _tracer = tracer if tracer is not None else NULL_TRACER
    return _tracer


@contextmanager
def tracing(tracer) -> Iterator[Any]:
    """Scoped `set_tracer`: restores the previous tracer on exit."""
    prev = _tracer
    set_tracer(tracer)
    try:
        yield _tracer
    finally:
        set_tracer(prev)
