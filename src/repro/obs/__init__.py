"""repro.obs — tracing, metrics and post-run analytics.

Stdlib-only and imported BY repro.core/api/serving (never the other way
around), so any layer can instrument itself without import cycles. See
docs/api.md "Observability".
"""
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .report import (GroupRecord, RunRecorder, RunReport, build_report,
                     scale_fit, scale_fit_mape, step_model_error,
                     straggler_scores, wave_stats)
from .trace import (NULL_TRACER, NullTracer, Tracer, get_tracer,
                    set_tracer, tracing, validate_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "GroupRecord", "RunRecorder", "RunReport", "build_report",
    "scale_fit", "scale_fit_mape", "step_model_error",
    "straggler_scores", "wave_stats",
    "NULL_TRACER", "NullTracer", "Tracer", "get_tracer", "set_tracer",
    "tracing", "validate_trace",
]
