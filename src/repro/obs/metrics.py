"""Metrics — a small registry of counters, gauges and histograms.

The tracer (obs/trace.py) answers "where did the time go"; the metrics
registry answers "how often / how much" for the signals the repro
already produces but only exposes as scattered attributes: executable
cache misses (`GroupPool.stats`), plan-cache hits/misses/nearest
references (`PlanCache.stats`), group reconfigurations, KV-cache page
occupancy, padding efficiency. `Engine` and `ServingEngine` each own a
`MetricsRegistry` and fold those signals in every step, so one
`snapshot()` at any point gives the whole picture and
`delta(previous_snapshot)` gives the per-window rates.

Semantics:
  * Counter   — monotonically increasing (`inc`); delta = new - old.
  * Gauge     — last-write-wins (`set`); delta = current value.
  * Histogram — `observe(v)` accumulates count/sum/min/max plus a
    bounded reservoir of recent samples for percentiles; snapshots are
    dicts, delta reports the count/sum increments.

Thread-safe (a single registry lock — these are cold-path updates, at
most a few per scheduled step) and stdlib-only, like the rest of
`repro.obs`.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, Optional, Union

Scalar = Union[int, float]


class Counter:
    """Monotonic counter."""

    kind = "counter"
    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: Scalar = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: inc({n}) < 0")
        with self._lock:
            self.value += n

    def snapshot(self) -> Scalar:
        return self.value


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: Scalar) -> None:
        self.value = float(v)

    def snapshot(self) -> Scalar:
        return self.value


class Histogram:
    """Streaming distribution: exact count/sum/min/max + a bounded
    reservoir of the most recent samples for approximate percentiles."""

    kind = "histogram"
    __slots__ = ("name", "count", "total", "min", "max", "_samples",
                 "_lock")

    def __init__(self, name: str, reservoir: int = 512):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: "deque[float]" = deque(maxlen=reservoir)
        self._lock = threading.Lock()

    def observe(self, v: Scalar) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            self._samples.append(v)

    def percentile(self, q: float) -> float:
        """q in [0, 1], computed over the recent-sample reservoir."""
        with self._lock:
            samples = sorted(self._samples)
        if not samples:
            return 0.0
        idx = min(len(samples) - 1, int(q * (len(samples) - 1) + 0.5))
        return samples[idx]

    def snapshot(self) -> Dict[str, Scalar]:
        with self._lock:
            if self.count == 0:
                return {"count": 0, "sum": 0.0, "mean": 0.0,
                        "min": 0.0, "max": 0.0, "p50": 0.0}
            samples = sorted(self._samples)
        p50 = samples[min(len(samples) - 1,
                          int(0.5 * (len(samples) - 1) + 0.5))]
        return {"count": self.count, "sum": self.total,
                "mean": self.total / self.count,
                "min": self.min, "max": self.max, "p50": p50}


class MetricsRegistry:
    """Name -> instrument map with get-or-create accessors.

    Re-requesting a name returns the SAME instrument (so call sites
    don't need to share handles); requesting an existing name as a
    different kind is a bug and raises.
    """

    def __init__(self):
        self._instruments: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{inst.kind}, requested {cls.kind}")
            return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, reservoir: int = 512) -> Histogram:
        return self._get(name, Histogram, reservoir=reservoir)

    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def snapshot(self) -> Dict[str, object]:
        """Point-in-time values: scalars for counters/gauges, summary
        dicts for histograms. JSON-serializable."""
        with self._lock:
            instruments = list(self._instruments.items())
        return {name: inst.snapshot() for name, inst in
                sorted(instruments)}

    def delta(self, prev: Optional[Dict[str, object]] = None
              ) -> Dict[str, object]:
        """Change since a previous `snapshot()`: counters and histogram
        count/sum report increments, gauges report their current value.
        Instruments absent from `prev` diff against zero."""
        prev = prev or {}
        out: Dict[str, object] = {}
        for name, value in self.snapshot().items():
            before = prev.get(name)
            if isinstance(value, dict):          # histogram
                b = before if isinstance(before, dict) else {}
                out[name] = {"count": value["count"] - b.get("count", 0),
                             "sum": value["sum"] - b.get("sum", 0.0)}
            else:
                inst = self._instruments[name]
                if isinstance(inst, Gauge):
                    out[name] = value
                else:
                    out[name] = value - (before if isinstance(
                        before, (int, float)) else 0)
        return out

    def update_from(self, stats: Dict[str, Scalar], prefix: str = ""
                    ) -> None:
        """Fold a plain stats dict (e.g. `PlanCache.stats`,
        `PoolStats.__dict__`) into gauges named `prefix + key`."""
        for key, value in stats.items():
            if isinstance(value, (int, float)):
                self.gauge(prefix + key).set(value)
