"""Post-run analytics — imbalance, stragglers, cost-model error.

The raw material is the per-group timing record the executor already
produces in measuring mode ({seq_ids, degree, seconds, compiled, ...}),
joined with the plan's rank-slot geometry (`ExecutionPlan.group_slots`)
and predicted group times (`GroupPlan.est_time`). `RunRecorder` captures
that join per executed step; `build_report` turns the records into the
three analyses the paper's evaluation revolves around:

  * per-wave load imbalance — max/mean measured group time within each
    wave (micro-batch), the Fig. 2 metric DHP exists to drive to 1.0;
  * per-rank straggler score — the mean of (group time / wave mean) over
    the waves a rank participates in; a healthy rank sits near 1.0, a
    straggler consistently above (the signal the ROADMAP's elastic
    runtime needs for exclusion decisions);
  * cost-model error — MAPE between predicted and measured group times.
    The analytic CostModel predicts *simulated device* seconds while the
    demo measures *host wall* seconds, so predictions are first scaled
    by the least-squares factor fit over the whole run (`scale`); MAPE
    of the scaled predictions is scale-free and measures exactly what
    the planner relies on — RELATIVE cost fidelity. This residual stream
    is the input signal for Entrain-style online recalibration.

Compile-tainted measurements (a group's first execution pays XLA
compilation, often 100x the step) are excluded the same way
OracleStrategy excludes them: waves containing any compiled group are
dropped from imbalance/straggler statistics and compiled groups from the
MAPE sample — unless that would leave nothing, in which case everything
is used and the report says so (`clean=False` waves).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence as Seq, Tuple

REPORT_VERSION = 1


@dataclasses.dataclass
class GroupRecord:
    """One executed group: where it ran, what the planner predicted,
    what the clock measured."""

    step: int
    wave: int            # micro-batch index within the step's plan
    group: int           # group index within the wave
    start_rank: int
    degree: int
    tokens: int
    predicted_s: float
    measured_s: float
    compiled: bool = False

    @property
    def ranks(self) -> range:
        return range(self.start_rank, self.start_rank + self.degree)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, obj: dict) -> "GroupRecord":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in obj.items() if k in names})


class RunRecorder:
    """Collects GroupRecords across a run.

    `Engine.train(trace=... / report=...)` installs one and feeds it
    from `execute()`; tests can also append synthetic records directly
    via `add()`."""

    def __init__(self, n_ranks: int):
        self.n_ranks = n_ranks
        self.records: List[GroupRecord] = []

    def add(self, record: GroupRecord) -> None:
        self.records.append(record)

    def record_step(self, step: int, plan, timings: Seq[dict]) -> None:
        """Join one executed plan with its measured per-group timings
        (executor dispatch order == plan group order == group_slots
        order)."""
        groups = [g for mb in plan.micro_batches for g in mb.groups]
        slots = plan.group_slots(self.n_ranks)
        for (mi, gi, start, degree), g, t in zip(slots, groups, timings):
            self.records.append(GroupRecord(
                step=step, wave=mi, group=gi, start_rank=start,
                degree=degree, tokens=g.tokens,
                predicted_s=float(g.est_time),
                measured_s=float(t["seconds"]),
                compiled=bool(t.get("compiled", False))))

    def __len__(self) -> int:
        return len(self.records)


# -- core statistics ----------------------------------------------------------
def scale_fit(pred: Seq[float], meas: Seq[float]) -> float:
    """Least-squares scale alpha minimizing sum((alpha*p - m)^2) — the
    simulated-seconds -> wall-seconds calibration factor."""
    num = sum(p * m for p, m in zip(pred, meas))
    den = sum(p * p for p in pred)
    return num / den if den > 0 else 0.0


def scale_fit_mape(pred: Seq[float], meas: Seq[float],
                   scale: Optional[float] = None
                   ) -> Tuple[float, float, int]:
    """(mape_pct, scale, n_samples) of scaled predictions vs
    measurements. Pairs with measured_s <= 0 are skipped; pass `scale`
    to reuse a fit from a larger sample (per-wave MAPE under the global
    calibration)."""
    pairs = [(p, m) for p, m in zip(pred, meas) if m > 0]
    if not pairs:
        return 0.0, 0.0, 0
    if scale is None:
        scale = scale_fit([p for p, _ in pairs], [m for _, m in pairs])
    errs = [abs(scale * p - m) / m for p, m in pairs]
    return 100.0 * sum(errs) / len(errs), scale, len(errs)


def step_model_error(plan, timings: Seq[dict]) -> float:
    """One step's cost-model MAPE (the StepMetrics.model_error_pct
    feed): scaled-prediction error over the step's non-compile-tainted
    groups; 0.0 when every group compiled (nothing clean to score)."""
    groups = [g for mb in plan.micro_batches for g in mb.groups]
    pred = [g.est_time for g, t in zip(groups, timings)
            if not t.get("compiled", False)]
    meas = [float(t["seconds"]) for t in timings
            if not t.get("compiled", False)]
    mape, _, n = scale_fit_mape(pred, meas)
    return mape if n else 0.0


def _waves(records: Seq[GroupRecord]) -> "Dict[Tuple[int, int], List[GroupRecord]]":
    by_wave: Dict[Tuple[int, int], List[GroupRecord]] = {}
    for r in records:
        by_wave.setdefault((r.step, r.wave), []).append(r)
    return by_wave


def wave_stats(records: Seq[GroupRecord]) -> List[dict]:
    """Per-wave load statistics, one dict per (step, wave):
    makespan (max measured group time), mean, and imbalance = max/mean —
    the paper's Fig. 2 metric. `clean` marks waves free of
    compile-tainted groups."""
    out = []
    for (step, wave), recs in sorted(_waves(records).items()):
        times = [r.measured_s for r in recs]
        mean = sum(times) / len(times)
        mx = max(times)
        out.append({
            "step": step, "wave": wave, "n_groups": len(recs),
            "makespan_s": mx, "mean_s": mean,
            "imbalance": mx / mean if mean > 0 else 1.0,
            "clean": not any(r.compiled for r in recs),
        })
    return out


def straggler_scores(records: Seq[GroupRecord], n_ranks: int
                     ) -> Dict[int, dict]:
    """Per-rank straggler score: mean over waves of (the rank's group
    time / the wave's mean group time). 1.0 = perfectly average; the
    injected-slow-rank test expects its ranks to score highest. Only
    clean (compile-free) waves count when any exist. Ranks that never
    participated report score 0.0 with waves=0."""
    by_wave = _waves(records)
    clean = {k: v for k, v in by_wave.items()
             if not any(r.compiled for r in v)}
    used = clean or by_wave
    ratios: Dict[int, List[float]] = {r: [] for r in range(n_ranks)}
    for recs in used.values():
        mean = sum(r.measured_s for r in recs) / len(recs)
        if mean <= 0:
            continue
        for rec in recs:
            for rank in rec.ranks:
                if 0 <= rank < n_ranks:
                    ratios[rank].append(rec.measured_s / mean)
    return {rank: {"score": (sum(v) / len(v)) if v else 0.0,
                   "waves": len(v)}
            for rank, v in ratios.items()}


# -- the report ---------------------------------------------------------------
@dataclasses.dataclass
class RunReport:
    """The post-run analytics document: JSON via to_json()/save(),
    humans via summary()."""

    n_ranks: int
    n_steps: int
    waves: List[dict]
    imbalance: Dict[str, float]
    stragglers: Dict[str, Any]
    model_error: Dict[str, Any]
    steps: List[dict] = dataclasses.field(default_factory=list)
    metrics: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "version": REPORT_VERSION,
            "n_ranks": self.n_ranks,
            "n_steps": self.n_steps,
            "waves": self.waves,
            "imbalance": self.imbalance,
            "stragglers": {
                **self.stragglers,
                "scores": {str(r): s for r, s in
                           self.stragglers.get("scores", {}).items()},
            },
            "model_error": self.model_error,
            "steps": self.steps,
            "metrics": self.metrics,
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    def summary(self) -> str:
        imb = self.imbalance
        me = self.model_error
        st = self.stragglers
        worst = st.get("worst_rank")
        worst_score = (st["scores"][worst]["score"]
                       if worst is not None and worst in st.get(
                           "scores", {}) else 0.0)
        lines = [
            f"run report: {self.n_steps} steps, {len(self.waves)} waves,"
            f" {self.n_ranks} ranks",
            f"  imbalance (max/mean group time per wave): "
            f"mean={imb.get('mean', 0.0):.3f} "
            f"max={imb.get('max', 0.0):.3f} "
            f"over {imb.get('n_waves', 0)} waves"
            + ("" if imb.get("clean", True) else
               " [compile-tainted: no clean wave available]"),
            f"  stragglers: worst rank={worst} "
            f"score={worst_score:.3f} "
            f"flagged(>{st.get('threshold', 0.0):.2f})="
            f"{st.get('flagged', [])}",
            f"  cost model: MAPE={me.get('mape_pct', 0.0):.1f}% over "
            f"{me.get('n_samples', 0)} groups "
            f"(wall/predicted scale={me.get('scale', 0.0):.3g})",
        ]
        return "\n".join(lines)


def build_report(recorder: RunRecorder,
                 history: Optional[Seq[Any]] = None,
                 metrics: Optional[Dict[str, Any]] = None,
                 straggler_threshold: float = 1.2) -> RunReport:
    """Records (+ optional StepMetrics history and a MetricsRegistry
    snapshot) -> RunReport."""
    records = recorder.records
    waves = wave_stats(records)
    clean_waves = [w for w in waves if w["clean"]] or waves
    imbalances = [w["imbalance"] for w in clean_waves]
    imbalance = {
        "mean": (sum(imbalances) / len(imbalances)) if imbalances else 0.0,
        "max": max(imbalances) if imbalances else 0.0,
        "n_waves": len(imbalances),
        "clean": bool(clean_waves) and all(w["clean"]
                                           for w in clean_waves),
    }

    scores = straggler_scores(records, recorder.n_ranks)
    active = {r: s for r, s in scores.items() if s["waves"] > 0}
    worst = (max(active, key=lambda r: active[r]["score"])
             if active else None)
    stragglers: Dict[str, Any] = {
        "scores": scores,
        "worst_rank": worst,
        "threshold": straggler_threshold,
        "flagged": sorted(r for r, s in active.items()
                          if s["score"] > straggler_threshold),
    }

    clean_recs = [r for r in records if not r.compiled] or list(records)
    mape, scale, n = scale_fit_mape(
        [r.predicted_s for r in clean_recs],
        [r.measured_s for r in clean_recs])
    per_wave = []
    for (step, wave), recs in sorted(_waves(clean_recs).items()):
        w_mape, _, w_n = scale_fit_mape(
            [r.predicted_s for r in recs],
            [r.measured_s for r in recs], scale=scale)
        if w_n:
            per_wave.append({"step": step, "wave": wave,
                             "mape_pct": w_mape})
    model_error = {"mape_pct": mape, "scale": scale, "n_samples": n,
                   "per_wave": per_wave}

    steps = [m.to_json() for m in history] if history else []
    return RunReport(
        n_ranks=recorder.n_ranks,
        n_steps=len({r.step for r in records}),
        waves=waves,
        imbalance=imbalance,
        stragglers=stragglers,
        model_error=model_error,
        steps=steps,
        metrics=dict(metrics or {}),
    )
