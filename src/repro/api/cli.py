"""`repro-train` — the Engine CLI (also `python -m repro.api.cli`).

One loop, every strategy:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  repro-train --arch internvl3-2b --strategy dhp --steps 20 --reduced
  repro-train --arch internvl3-2b --strategy static --steps 20 --reduced
  repro-train --list-strategies

Plan IR persistence (docs/api.md "Plan IR & replay"):

  repro-train --steps 10 --save-plans plans.json     # record the trace
  repro-train --replay-plans plans.json              # bit-identical rerun
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from ..core.scheduler import load_plans, save_plans
from .cluster import ClusterSpec
from .engine import Engine, StepMetrics
from .strategies import (ReplayStrategy, available_strategies,
                         get_strategy)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-train",
        description="Train via the unified Engine with a pluggable "
                    "parallelism strategy.")
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--strategy", default=None,
                    choices=available_strategies(),
                    help="parallelism strategy (default: dhp; "
                    "launch.train keeps its legacy static default)")
    ap.add_argument("--mode", default=None,
                    choices=available_strategies(),
                    help="deprecated alias for --strategy")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch (sequences per step)")
    ap.add_argument("--seq-len", type=int, default=512,
                    help="max tokens per sequence")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized model variant")
    ap.add_argument("--dataset", default="openvid")
    ap.add_argument("--mem-budget", type=float, default=1024.0,
                    help="per-rank activation budget in tokens (demo)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--list-strategies", action="store_true")
    ap.add_argument("--save-plans", metavar="PATH", default=None,
                    help="write the executed plan trace (Plan IR v2 "
                    "JSON) to PATH for later --replay-plans")
    ap.add_argument("--replay-plans", metavar="PATH", default=None,
                    help="replay a saved plan trace instead of "
                    "planning (bit-identical group assignments)")
    ap.add_argument("--no-lookahead", action="store_true",
                    help="disable the planner pipeline: plan each "
                    "batch synchronously before executing it")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace-event JSON timeline of "
                    "the run to PATH (open at https://ui.perfetto.dev); "
                    "switches execution to measuring mode")
    ap.add_argument("--report", metavar="PATH", default=None,
                    help="write the post-run analytics report "
                    "(imbalance, stragglers, cost-model MAPE) to PATH "
                    "as JSON; implies measuring mode")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="write per-step StepMetrics history to PATH "
                    "as JSON")
    return ap


def make_engine(args, default_strategy: str = "dhp") -> Engine:
    """argparse namespace -> configured Engine (shared with the
    deprecated launch.train shims)."""
    from ..training.optimizer import AdamW, cosine_schedule

    replay = getattr(args, "replay_plans", None)
    if replay:
        strategy = ReplayStrategy(plans=load_plans(replay))
    else:
        name = (getattr(args, "strategy", None)
                or getattr(args, "mode", None) or default_strategy)
        strategy = get_strategy(name)
    cluster = ClusterSpec.auto(mem_budget=args.mem_budget)
    return Engine(
        args.arch,
        cluster,
        strategy=strategy,
        optimizer=AdamW(lr=cosine_schedule(args.lr, 10, args.steps)),
        reduced=args.reduced,
        seed=args.seed,
    )


def run(args, default_strategy: str = "dhp") -> List[StepMetrics]:
    """Build an Engine from CLI args and train — the whole driver."""
    engine = make_engine(args, default_strategy)
    print(f"arch={engine.cfg.arch_id} strategy={engine.strategy.name} "
          f"ranks={engine.cluster.n_replicas}")
    steps = args.steps
    if getattr(args, "replay_plans", None):
        steps = min(steps, len(engine.strategy))
        print(f"replaying {steps} recorded plans from "
              f"{args.replay_plans}")
    plan_log: Optional[list] = (
        [] if getattr(args, "save_plans", None) else None)
    trace = getattr(args, "trace", None)
    report = getattr(args, "report", None)
    history = engine.train(
        steps=steps, dataset=args.dataset,
        global_batch=args.batch, max_tokens=args.seq_len,
        lookahead=not getattr(args, "no_lookahead", False),
        plan_log=plan_log, log=print,
        trace=trace, report=report or bool(trace))
    print("executable pool:", engine.executor.pool.stats)
    cache = engine.strategy.plan_cache
    if cache is not None:
        print("plan cache:", cache.stats)
    if plan_log is not None:
        save_plans(args.save_plans, plan_log)
        print(f"saved {len(plan_log)} plans -> {args.save_plans}")
    if trace:
        print(f"saved trace -> {trace}")
    if engine.last_report is not None:
        print(engine.last_report.summary())
        if report:
            print(f"saved report -> {report}")
    metrics_path = getattr(args, "metrics", None)
    if metrics_path:
        import json

        from .engine import metrics_to_json
        with open(metrics_path, "w") as f:
            json.dump(metrics_to_json(history), f, indent=1)
        print(f"saved metrics -> {metrics_path}")
    if args.checkpoint:
        engine.save_checkpoint(args.checkpoint)
        print("saved", args.checkpoint)
    engine.close()
    return history


def main(argv: Optional[List[str]] = None, *,
         default_strategy: str = "dhp") -> None:
    args = build_parser().parse_args(argv)
    if args.list_strategies:
        for name in available_strategies():
            print(name)
        return
    run(args, default_strategy)


# ---------------------------------------------------------------------------
# `repro-serve` — the continuous-batching serving runtime CLI
# ---------------------------------------------------------------------------
def build_serve_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-serve",
        description="Serve a synthetic heterogeneous request trace "
                    "through the continuous-batching runtime "
                    "(DHP-planned chunked prefill + paged KV cache).")
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized model variant")
    ap.add_argument("--requests", type=int, default=16,
                    help="trace length (number of requests)")
    ap.add_argument("--dataset", default="openvid",
                    choices=("msrvtt", "internvid", "openvid"),
                    help="prompt-length distribution (paper Fig. 1)")
    ap.add_argument("--slots", type=int, default=4,
                    help="decode slots (bucketed to the pow2 ladder)")
    ap.add_argument("--max-prompt", type=int, default=192)
    ap.add_argument("--mean-new", type=int, default=16,
                    help="mean generated tokens per request (geometric)")
    ap.add_argument("--max-new", type=int, default=48)
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="max prompt tokens prefetched per request per "
                    "iteration (chunked prefill)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="Poisson arrival rate (requests/s); default: "
                    "all requests arrive at t=0")
    ap.add_argument("--strategy", default="dhp",
                    help="prefill grouping strategy (registry name)")
    ap.add_argument("--checkpoint", default=None,
                    help="load params from a checkpoint before serving")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace-event JSON timeline of "
                    "the serving loop (prefill/decode spans, KV and "
                    "queue counter tracks) to PATH")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def serve_main(argv: Optional[List[str]] = None) -> None:
    import numpy as np

    from ..serving.trace import sample_trace

    args = build_serve_parser().parse_args(argv)
    engine = Engine(args.arch, ClusterSpec.auto(),
                    strategy=args.strategy, reduced=args.reduced,
                    seed=args.seed)
    if args.checkpoint:
        engine.load_checkpoint(args.checkpoint)
    rng = np.random.default_rng(args.seed)
    trace = sample_trace(
        args.dataset, args.requests, rng, vocab=engine.cfg.vocab,
        max_prompt=args.max_prompt, mean_new_tokens=args.mean_new,
        max_new_tokens=args.max_new, arrival_rate=args.arrival_rate)
    srv = engine.serving(slots=args.slots,
                         prefill_chunk=args.prefill_chunk,
                         strategy=args.strategy)
    print(f"arch={engine.cfg.arch_id} family={engine.cfg.family} "
          f"slots={srv.n_slots} requests={len(trace)} "
          f"dataset={args.dataset}")
    report = srv.run(trace, log=print, trace=args.trace)
    print(report.summary())
    if args.trace:
        print(f"saved trace -> {args.trace}")
    print(f"kv: peak_blocks={report.peak_kv_blocks} "
          f"occupancy_max={max(report.kv_occupancy):.2f} "
          f"cache_len={report.cache_len}")
    print(f"planner: schedule={report.schedule_ms:.1f}ms "
          f"plan_cache={report.plan_cache}")
    engine.close()


if __name__ == "__main__":
    main()
