"""`repro-train` — the Engine CLI (also `python -m repro.api.cli`).

One loop, every strategy:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  repro-train --arch internvl3-2b --strategy dhp --steps 20 --reduced
  repro-train --arch internvl3-2b --strategy static --steps 20 --reduced
  repro-train --list-strategies
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from .cluster import ClusterSpec
from .engine import Engine, StepMetrics
from .strategies import available_strategies, get_strategy


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro-train",
        description="Train via the unified Engine with a pluggable "
                    "parallelism strategy.")
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--strategy", default=None,
                    choices=available_strategies(),
                    help="parallelism strategy (default: dhp; "
                    "launch.train keeps its legacy static default)")
    ap.add_argument("--mode", default=None,
                    choices=available_strategies(),
                    help="deprecated alias for --strategy")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8,
                    help="global batch (sequences per step)")
    ap.add_argument("--seq-len", type=int, default=512,
                    help="max tokens per sequence")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized model variant")
    ap.add_argument("--dataset", default="openvid")
    ap.add_argument("--mem-budget", type=float, default=1024.0,
                    help="per-rank activation budget in tokens (demo)")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--list-strategies", action="store_true")
    return ap


def make_engine(args, default_strategy: str = "dhp") -> Engine:
    """argparse namespace -> configured Engine (shared with the
    deprecated launch.train shims)."""
    from ..training.optimizer import AdamW, cosine_schedule

    strategy = (getattr(args, "strategy", None)
                or getattr(args, "mode", None) or default_strategy)
    cluster = ClusterSpec.auto(mem_budget=args.mem_budget)
    return Engine(
        args.arch,
        cluster,
        strategy=get_strategy(strategy),
        optimizer=AdamW(lr=cosine_schedule(args.lr, 10, args.steps)),
        reduced=args.reduced,
        seed=args.seed,
    )


def run(args, default_strategy: str = "dhp") -> List[StepMetrics]:
    """Build an Engine from CLI args and train — the whole driver."""
    engine = make_engine(args, default_strategy)
    print(f"arch={engine.cfg.arch_id} strategy={engine.strategy.name} "
          f"ranks={engine.cluster.n_replicas}")
    history = engine.train(
        steps=args.steps, dataset=args.dataset,
        global_batch=args.batch, max_tokens=args.seq_len, log=print)
    print("executable pool:", engine.executor.pool.stats)
    if args.checkpoint:
        engine.save_checkpoint(args.checkpoint)
        print("saved", args.checkpoint)
    engine.close()
    return history


def main(argv: Optional[List[str]] = None, *,
         default_strategy: str = "dhp") -> None:
    args = build_parser().parse_args(argv)
    if args.list_strategies:
        for name in available_strategies():
            print(name)
        return
    run(args, default_strategy)


if __name__ == "__main__":
    main()
