"""repro.api — the unified engine: one Session facade, pluggable
Strategy backends, and a ClusterSpec that owns the device topology.

Lifecycle (see docs/api.md):

    cluster = ClusterSpec.auto(mem_budget=900.0)
    engine  = Engine("internvl3-2b", cluster, strategy="dhp",
                     reduced=True)
    metrics = engine.train(steps=20, dataset="openvid", global_batch=12)
    tokens, report = engine.serve(gen_tokens=16)

Strategies are registry entries — `get_strategy("dhp")`,
`get_strategy("static")`, `get_strategy("bruteforce")`,
`get_strategy("oracle")` — so adding a parallelism policy is one class
with a `@register_strategy` decorator, not a new driver.
"""
from ..core.cost_model import MMSequence, ModalitySpan
from ..core.scheduler import (PLAN_IR_VERSION, ExecutionPlan, GroupDelta,
                              PlanCache, PlanValidationError, diff_plans,
                              load_plans, save_plans)
from ..serving.runtime import ServeReport, ServingEngine
from ..serving.scheduler import ServeRequest
from ..serving.trace import sample_trace
from .cluster import ClusterSpec
from .engine import Engine, Session, StepMetrics, demo_cost_model
from .strategies import (STRATEGY_REGISTRY, BruteForceStrategy,
                         DHPStrategy, MeasuredCostModel, OracleStrategy,
                         ReplayStrategy, StaticStrategy, Strategy,
                         available_strategies, get_strategy,
                         register_strategy)

__all__ = [
    "ClusterSpec",
    "Engine", "Session", "StepMetrics", "demo_cost_model",
    "Strategy", "StaticStrategy", "DHPStrategy", "BruteForceStrategy",
    "OracleStrategy", "MeasuredCostModel", "ReplayStrategy",
    "STRATEGY_REGISTRY", "available_strategies", "get_strategy",
    "register_strategy",
    "MMSequence", "ModalitySpan",
    "PLAN_IR_VERSION", "ExecutionPlan", "GroupDelta", "PlanCache",
    "PlanValidationError", "diff_plans", "save_plans", "load_plans",
    "ServingEngine", "ServeReport", "ServeRequest", "sample_trace",
]
