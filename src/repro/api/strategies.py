"""Pluggable parallelism strategies — the plan() half of the Session.

The paper's core claim is that the parallelism layout should be a
*per-batch, swappable decision*. This module makes the swap a one-word
registry lookup: every backend implements the same `Strategy` surface
(`plan`, async `prepare`/`collect`, `observe`) and is registered under a
name, so drivers, examples and benchmarks select layouts with
`get_strategy("dhp" | "static" | "megatron" | "deepspeed" |
"bruteforce" | "oracle")` instead of wiring scheduler classes by hand.

Adding a new parallelism strategy is now one class + one
`@register_strategy` line — no new driver.

Strategies are constructed *unbound* (no cluster context) and attached
to a cost model / rank count / memory budget via `bind(...)`, which the
Engine does automatically from its ClusterSpec.
"""
from __future__ import annotations

import collections
import concurrent.futures
import threading
import time
from typing import (Callable, Dict, List, Optional, Sequence as Seq,
                    Tuple, Union)

import numpy as np

from ..core.allocator import allocate_bruteforce, evaluate_degrees
from ..core.cost_model import CostModel, SeqInfo, as_seq_infos
from ..core.group_pool import pow2_bucket
from ..core.scheduler import (DHPScheduler, ExecutionPlan, PlanCache,
                              static_plan)
from ..obs.trace import get_tracer

# name -> (class, constructor defaults). Aliases ("megatron") are just
# extra entries with different defaults.
STRATEGY_REGISTRY: Dict[str, Tuple[type, dict]] = {}


def register_strategy(name: str, **defaults):
    """Class decorator registering a Strategy backend under `name`."""
    def deco(cls):
        STRATEGY_REGISTRY[name] = (cls, dict(defaults))
        return cls
    return deco


def available_strategies() -> List[str]:
    return sorted(STRATEGY_REGISTRY)


def get_strategy(name: str, **options) -> "Strategy":
    """Registry round-trip: name -> configured Strategy instance.

    `options` override the registered defaults (e.g.
    `get_strategy("static", degree=4)`)."""
    if name not in STRATEGY_REGISTRY:
        raise KeyError(
            f"unknown strategy {name!r}; registered: "
            f"{available_strategies()}")
    cls, defaults = STRATEGY_REGISTRY[name]
    strat = cls(**{**defaults, **options})
    strat.name = name
    return strat


class Strategy:
    """One parallelism policy: turns a batch of SeqInfo into an
    ExecutionPlan the executor can run.

    Subclasses implement `_plan`. The base class provides the uniform
    async producer-consumer surface (`prepare` schedules the NEXT batch
    on a host thread while devices crunch the current one — paper §5
    Implementation (2)) and the `observe` hook fed with measured
    per-group timings after execution.
    """

    name = "strategy"
    #: engines pass per-group measured timings to observe() only when
    #: this is True (measuring serialises group dispatch).
    wants_measurement = False
    #: planners derive the plan's span table from the input batch;
    #: strategies that return externally RECORDED plans (replay) keep
    #: the plan's own seq_spans — overwriting would change the
    #: structural hash the trace was saved (and verified) with.
    attaches_spans = True

    def __init__(self, cost_model: Optional[CostModel] = None,
                 n_ranks: Optional[int] = None,
                 mem_budget: Optional[float] = None,
                 plan_cache: Union[None, bool, PlanCache] = None):
        """`plan_cache` controls cross-batch plan reuse: True/None
        enables the structural-histogram PlanCache (None defers to the
        class default — off for measuring strategies, whose cost model
        drifts under observation), False disables it, or pass a
        PlanCache instance to share one across strategies."""
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = mem_budget
        self._plan_cache_opt = plan_cache
        self._cache: Optional[PlanCache] = (
            plan_cache if isinstance(plan_cache, PlanCache) else None)
        self._executor: Optional[
            concurrent.futures.ThreadPoolExecutor] = None
        #: FIFO of in-flight background plans (lookahead window): each
        #: prepare() appends a future, collect() pops the oldest.
        self._pending: "collections.deque[concurrent.futures.Future]" = \
            collections.deque()
        #: ms collect() actually blocked waiting for the background
        #: planner — the NON-hidden share of schedule_ms.
        self.last_wait_ms: float = 0.0

    # -- binding ---------------------------------------------------------
    @property
    def is_bound(self) -> bool:
        return (self.cm is not None and self.n_ranks is not None
                and self.budget is not None)

    def bind(self, cost_model: CostModel, n_ranks: int,
             mem_budget: float) -> "Strategy":
        """Attach cluster context; fields already set (e.g. passed to the
        constructor explicitly) win. Returns self for chaining."""
        if self.cm is None:
            self.cm = cost_model
        if self.n_ranks is None:
            self.n_ranks = n_ranks
        if self.budget is None:
            self.budget = mem_budget
        self._rebind()
        return self

    def _rebind(self) -> None:
        """Subclass hook: invalidate planner caches after bind()."""

    def _require_bound(self) -> None:
        if not self.is_bound:
            raise RuntimeError(
                f"strategy {self.name!r} is unbound — call "
                f".bind(cost_model, n_ranks, mem_budget) or hand it to "
                f"an Engine first")

    # -- plan cache ------------------------------------------------------
    @property
    def plan_cache(self) -> Optional[PlanCache]:
        """The strategy's PlanCache, or None when caching is off."""
        if self._cache is None and self._plan_cache_opt is not False:
            if self._plan_cache_opt is None and self.wants_measurement:
                return None     # measured costs drift; never serve stale
            self._cache = PlanCache()
        return self._cache

    # -- planning --------------------------------------------------------
    def plan(self, seqs: Seq[SeqInfo]) -> ExecutionPlan:
        """Plan one batch. Accepts `SeqInfo`s, `MMSequence`s, or a mix —
        multimodal sequences are planned through their SeqInfo view
        (length and Eq. 8 eta derived from the span geometry) and the
        span table is attached to the resulting plan (`seq_spans`), so
        saved traces record the structure their costs came from."""
        self._require_bound()
        seqs = as_seq_infos(seqs)
        t0 = time.perf_counter()
        cache = self.plan_cache
        plan = None
        if cache is not None:
            plan = cache.lookup(seqs, cost_model=self.cm,
                                n_ranks=self.n_ranks,
                                mem_budget=self.budget)
            if plan is not None:
                ms = (time.perf_counter() - t0) * 1e3
                plan.schedule_ms = ms
                plan.stage_ms = {"cache": ms}
        if plan is None:
            plan = self._plan(seqs)
            if cache is not None:
                cache.store(seqs, plan)
        if self.attaches_spans:
            spans = {s.seq_id: tuple(s.spans) for s in seqs
                     if getattr(s, "spans", None)}
            plan.seq_spans = spans or None
        plan.strategy_name = self.name
        tr = get_tracer()
        if tr.enabled:
            # emitted from whichever thread ran the solve — the
            # lookahead planner thread gets its own trace track
            tr.complete("plan", t0, time.perf_counter() - t0, "planner",
                        args={"strategy": self.name,
                              "seqs": len(seqs),
                              "cache_hit": plan.from_cache,
                              "replan_mode": plan.replan_mode,
                              "schedule_ms": plan.schedule_ms})
        return plan

    def _plan(self, seqs: List[SeqInfo]) -> ExecutionPlan:
        raise NotImplementedError

    # -- async producer-consumer ----------------------------------------
    @property
    def n_pending(self) -> int:
        """In-flight background plans (the current lookahead depth)."""
        return len(self._pending)

    def prepare(self, seqs: Seq[SeqInfo]) -> None:
        """Kick off planning of the NEXT batch on the host thread.

        May be called several times before the matching collect()s: the
        futures form a FIFO lookahead window, all served by ONE planner
        thread so a window of batches t+1..t+k is solved back-to-back —
        consecutive solves share the scheduler's incremental-allocator
        state (warm DP rows, cost tables), which is what makes the
        batched lookahead cheap (see docs/api.md "Planner
        performance")."""
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1)
        self._pending.append(self._executor.submit(self.plan, list(seqs)))

    def prepare_many(self, batches: Seq[Seq[SeqInfo]]) -> None:
        """Enqueue a whole lookahead window t+1..t+k at once."""
        for seqs in batches:
            self.prepare(seqs)

    def collect(self) -> ExecutionPlan:
        """Block until the OLDEST prepared plan is ready (usually is).

        Records `last_wait_ms`, the time this call actually blocked —
        `schedule_ms - last_wait_ms` is the planning latency hidden
        behind device execution (StepMetrics.plan_overlap_ms)."""
        if not self._pending:
            raise RuntimeError("collect() without a prior prepare()")
        t0 = time.perf_counter()
        plan = self._pending.popleft().result()
        self.last_wait_ms = (time.perf_counter() - t0) * 1e3
        return plan

    # -- feedback --------------------------------------------------------
    def observe(self, plan: ExecutionPlan,
                timings: List[dict]) -> None:
        """Post-execution hook with measured per-group timings
        ({seq_ids, degree, tokens, seconds, compiled} dicts). Default:
        ignored; OracleStrategy learns its cost table from these."""

    def close(self) -> None:
        self._pending.clear()
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None


# ---------------------------------------------------------------------------
@register_strategy("static")
@register_strategy("megatron", power_of_two=False)
@register_strategy("deepspeed", power_of_two=True)
class StaticStrategy(Strategy):
    """Fixed-degree baseline (Megatron-LM / DeepSpeed style).

    `degree=None` sizes the one global CP degree for the longest
    sequence of each batch (how a practitioner must configure a static
    system); `power_of_two=True` adds the Ulysses head-divisibility
    rounding (§4.1)."""

    def __init__(self, cost_model=None, n_ranks=None, mem_budget=None, *,
                 degree: Optional[int] = None, power_of_two: bool = False,
                 plan_cache=None):
        super().__init__(cost_model, n_ranks, mem_budget, plan_cache)
        self.degree = degree
        self.power_of_two = power_of_two

    def _plan(self, seqs):
        return static_plan(seqs, self.cm, self.n_ranks, self.budget,
                           degree=self.degree,
                           power_of_two=self.power_of_two)


@register_strategy("dhp")
@register_strategy("dhp-faithful", balance_packing=False,
                   serial_fallback=False)
class DHPStrategy(Strategy):
    """The paper's system: memory-aware BFD packing (Stage 1) + 2D-DP
    resource assignment (Stage 2), re-planned every global batch."""

    def __init__(self, cost_model=None, n_ranks=None, mem_budget=None, *,
                 use_all_ranks: bool = True, balance_packing: bool = True,
                 serial_fallback: bool = True,
                 allocator: Optional[Callable] = None,
                 plan_cache=None):
        super().__init__(cost_model, n_ranks, mem_budget, plan_cache)
        self.options = dict(use_all_ranks=use_all_ranks,
                            balance_packing=balance_packing,
                            serial_fallback=serial_fallback,
                            allocator=allocator)
        self._scheduler: Optional[DHPScheduler] = None

    def _rebind(self):
        self._scheduler = None

    @property
    def scheduler(self) -> DHPScheduler:
        self._require_bound()
        if self._scheduler is None:
            self._scheduler = DHPScheduler(
                self.cm, self.n_ranks, self.budget, **self.options)
        return self._scheduler

    def _plan(self, seqs):
        return self.scheduler.schedule(seqs)


@register_strategy("bruteforce")
class BruteForceStrategy(DHPStrategy):
    """DHP with the exact exhaustive Stage-2 solver instead of the 2D-DP
    — the optimality oracle for the allocator (only tractable on small
    waves; used by tests and regret analyses)."""

    def __init__(self, cost_model=None, n_ranks=None, mem_budget=None, *,
                 balance_packing: bool = True, plan_cache=None):
        super().__init__(cost_model, n_ranks, mem_budget,
                         balance_packing=balance_packing,
                         serial_fallback=False,
                         allocator=allocate_bruteforce,
                         plan_cache=plan_cache)


# ---------------------------------------------------------------------------
class MeasuredCostModel(CostModel):
    """Cost model backed by post-hoc measurements.

    Keeps a running mean of measured group seconds keyed by
    (pow2 token bucket, degree) — the same key space as the executable
    pool, so every shape the executor has actually run has an entry —
    plus a global measured/predicted calibration ratio that scales the
    analytic estimate for shapes never measured."""

    def __init__(self, base: CostModel):
        super().__init__(base.coeffs, base.hw)
        self._base = base
        self._meas: Dict[Tuple[int, int], List[float]] = {}  # key -> [sum, n]
        self._ratio_sum = 0.0
        self._ratio_n = 0
        # record() runs on the engine's main thread while the strategy's
        # background planning thread reads group_time() concurrently
        self._lock = threading.Lock()

    @staticmethod
    def _key(tokens: int, degree: int) -> Tuple[int, int]:
        return (pow2_bucket(int(tokens), 64), int(degree))

    @property
    def n_samples(self) -> int:
        return int(sum(n for _, n in self._meas.values()))

    def record(self, tokens: int, degree: int, seconds: float) -> None:
        pred = self._base.group_time(
            [SeqInfo(length=int(tokens))], int(degree))
        key = self._key(tokens, degree)
        with self._lock:
            ent = self._meas.setdefault(key, [0.0, 0])
            ent[0] += seconds
            ent[1] += 1
            if pred > 0:
                self._ratio_sum += seconds / pred
                self._ratio_n += 1
            # predictions just changed: invalidate warm-started
            # allocator states keyed to the previous version
            self.cost_version += 1

    def group_time(self, seqs, degree):
        if not seqs:
            return 0.0
        tokens = sum(s.length for s in seqs)
        with self._lock:
            ent = self._meas.get(self._key(tokens, degree))
            if ent is not None:
                return ent[0] / ent[1]
            ratio = (self._ratio_sum / self._ratio_n
                     if self._ratio_n else 1.0)
        return self._base.group_time(seqs, degree) * ratio

    def group_time_vector(self, seqs, degrees):
        """Measured lookups are per-(bucket, degree) — no closed form to
        vectorize, so the bulk cost-table path degrades to scalar calls
        (still one call per table CELL, not per DP probe)."""
        return np.array([self.group_time(seqs, int(d)) for d in degrees])


@register_strategy("oracle")
class OracleStrategy(DHPStrategy):
    """DHP planning against *measured* costs instead of the analytic
    model — the hindsight planner for regret analysis.

    Engines running this strategy execute in measuring mode; every
    finished group feeds `observe()`, which updates a MeasuredCostModel
    (compile-tainted first executions are skipped). Plans therefore
    converge to what a scheduler with a perfect cost oracle would have
    chosen; `plan_cost(plan, seqs)` evaluates ANY plan under the measured
    costs, so `plan_cost(model_plan) - plan_cost(oracle_plan)` is the
    cost-model regret."""

    wants_measurement = True

    def __init__(self, cost_model=None, n_ranks=None, mem_budget=None, *,
                 use_all_ranks: bool = True, balance_packing: bool = True,
                 serial_fallback: bool = True, plan_cache=None):
        super().__init__(cost_model, n_ranks, mem_budget,
                         use_all_ranks=use_all_ranks,
                         balance_packing=balance_packing,
                         serial_fallback=serial_fallback,
                         plan_cache=plan_cache)

    def bind(self, cost_model, n_ranks, mem_budget):
        if self.cm is None and not isinstance(cost_model,
                                              MeasuredCostModel):
            self.cm = MeasuredCostModel(cost_model)
        return super().bind(cost_model, n_ranks, mem_budget)

    @property
    def measured(self) -> MeasuredCostModel:
        self._require_bound()
        if not isinstance(self.cm, MeasuredCostModel):
            self.cm = MeasuredCostModel(self.cm)
            self._rebind()
        return self.cm

    def observe(self, plan, timings):
        for t in timings:
            if t.get("compiled"):
                continue           # first run pays XLA compile, not step
            self.measured.record(t["tokens"], t["degree"], t["seconds"])

    def plan_cost(self, plan: ExecutionPlan,
                  seqs: Seq[SeqInfo]) -> float:
        """Evaluate an arbitrary plan under the measured cost table."""
        by_id = {s.seq_id: s for s in seqs}
        total = 0.0
        for mb in plan.micro_batches:
            total += evaluate_degrees(
                [[by_id[i] for i in g.seq_ids] for g in mb.groups],
                [g.degree for g in mb.groups],
                self.measured.group_time).makespan
        return total


# ---------------------------------------------------------------------------
class ReplayStrategy(Strategy):
    """Replays a saved plan trace instead of planning.

    Constructed directly (NOT in the registry — it is parameterized by
    the plans to replay): `ReplayStrategy(plans=load_plans(path))`, or
    via `repro-train --replay-plans plans.json`. Each `plan()` call pops
    the next recorded plan and validates its seq-id coverage against the
    batch it is about to execute, so a drifted data stream fails loudly
    instead of silently misassigning sequences. Replay is bit-identical:
    structural hashes, rank slots and executable keys match the run the
    plans were saved from (given the same loader seed/state).
    """

    name = "replay"
    attaches_spans = False      # recorded plans keep their saved hash

    def __init__(self, cost_model=None, n_ranks=None, mem_budget=None, *,
                 plans: Optional[Seq[ExecutionPlan]] = None):
        super().__init__(cost_model, n_ranks, mem_budget,
                         plan_cache=False)
        self._plans = list(plans or [])
        self._cursor = 0

    def __len__(self) -> int:
        return len(self._plans) - self._cursor

    def _plan(self, seqs):
        if self._cursor >= len(self._plans):
            raise RuntimeError(
                f"replay exhausted after {len(self._plans)} plans")
        recorded = self._plans[self._cursor]
        self._cursor += 1
        if isinstance(recorded, dict):
            recorded = ExecutionPlan.from_json(recorded)
        recorded.validate(seqs, n_ranks=self.n_ranks)
        return recorded
