"""Engine / Session — the single public entry point of the repro.

One facade owns the full lifecycle the paper's Fig. 3 describes:

    ClusterSpec  ──►  Engine(model, cluster, strategy="dhp")
                         │ plan(batch)    -> ExecutionPlan
                         │ execute(plan)  -> StepMetrics
                         │ train(loader)  -> [StepMetrics]  (async built in)
                         │ serve(...)     -> decoded tokens
                         ▼
                      Strategy registry (static / dhp / bruteforce / oracle)

`train()` is the one driver every launcher/example/benchmark shares: a
producer-consumer loop that prepares the NEXT batch's plan on a host
thread while devices execute the current one (paper §5 Implementation
(2)), parameterized only by the strategy name.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Union

from ..configs import get_config
from ..configs.base import ModelConfig
from ..core.cost_model import CostModel, SeqInfo, analytic_coeffs
from ..core.executor import DHPExecutor
from ..core.scheduler import ExecutionPlan, diff_plans
from ..data.pipeline import HeterogeneousLoader, RaggedBatch
from ..obs import (MetricsRegistry, RunRecorder, RunReport, Tracer,
                   build_report, step_model_error, tracing)
from .cluster import ClusterSpec
from .strategies import Strategy, get_strategy

Batch = Union[RaggedBatch, List[SeqInfo]]


@dataclasses.dataclass
class StepMetrics:
    """What one executed plan produced — the uniform result row every
    driver prints and every benchmark aggregates."""

    step: int
    loss: float
    tokens: int
    step_time_s: float
    strategy: str
    schedule_ms: float
    solver_ms: float
    stage_ms: Dict[str, float]
    degree_histogram: Dict[int, int]
    #: real/padded token ratio of the executed step (1.0 = no padding)
    padding_efficiency: float = 1.0
    #: executables compiled during this step (0 once the pool is warm)
    exe_misses: int = 0
    #: True when the plan came from the strategy's PlanCache (the DP
    #: solver was skipped for a recurring batch shape)
    plan_cache_hit: bool = False
    #: group slots created/resized vs the previous plan (GroupDelta)
    groups_reconfigured: int = 0
    #: planning latency hidden behind device execution by the lookahead
    #: pipeline (schedule_ms minus the time collect() actually blocked)
    plan_overlap_ms: float = 0.0
    #: tokens per modality in the executed batch ({"text": .., "vision":
    #: ..}); sequences without span structure count as "text"
    modality_tokens: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    #: Stage-2 allocator time for this plan (cost table + DP), in us —
    #: the millisecond-class-planning budget check_regression gates
    allocate_us: float = 0.0
    #: which planning path produced the plan: "full" | "incremental"
    #: (warm-started DP suffix) | "cache" (PlanCache hit)
    replan_mode: str = "full"
    #: mean next-token NLL per label-token modality class for
    #: span-bearing batches ({"text": .., "vision": ..}). Classes whose
    #: labels are excluded from the TRAINING loss (bidirectional spans)
    #: still report their NLL here for monitoring.
    modality_loss: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    #: cost-model MAPE of this step's scaled predicted vs measured
    #: group times (obs.report.step_model_error); 0.0 on unmeasured
    #: steps and steps where every group paid XLA compilation
    model_error_pct: float = 0.0
    #: the strategy's PlanCache.stats snapshot after this step (hits,
    #: misses, size, nearest_* reference counters); {} when caching off
    plan_cache: Dict[str, int] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        cached = " cached" if self.plan_cache_hit else ""
        return (f"step {self.step:3d} loss={self.loss:.4f} "
                f"degrees={self.degree_histogram} "
                f"sched={self.schedule_ms:.1f}ms{cached} "
                f"reconf={self.groups_reconfigured} "
                f"({self.step_time_s:.2f}s)")

    # -- serialization: THE StepMetrics wire format ---------------------
    def to_json(self) -> dict:
        """JSON-serializable dict; `from_json` round-trips it exactly.
        Every consumer (Engine history dumps, benchmarks, the obs run
        report) uses this instead of ad-hoc field plucking."""
        d = dataclasses.asdict(self)
        # JSON object keys are strings; stringify the int degree keys
        d["degree_histogram"] = {str(k): v for k, v
                                 in self.degree_histogram.items()}
        return d

    @classmethod
    def from_json(cls, obj: dict) -> "StepMetrics":
        names = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in obj.items() if k in names}
        kw["degree_histogram"] = {
            int(k): int(v)
            for k, v in (kw.get("degree_histogram") or {}).items()}
        return cls(**kw)


def metrics_to_json(history: List["StepMetrics"]) -> dict:
    """A training history as one JSON document (the --metrics file)."""
    return {"version": 1, "steps": [m.to_json() for m in history]}


def metrics_from_json(obj: dict) -> List["StepMetrics"]:
    steps = obj["steps"] if isinstance(obj, dict) else obj
    return [StepMetrics.from_json(s) for s in steps]


def demo_cost_model(cfg: ModelConfig) -> CostModel:
    """The CPU-demo calibration every driver used to hand-roll: roofline
    coefficients for the model shape, with memory accounting in plain
    tokens (m_token=1, m_ms=0) so `mem_budget` reads as a per-rank token
    budget."""
    coeffs = dataclasses.replace(
        analytic_coeffs(
            hidden=cfg.d_model, n_layers=cfg.n_layers,
            n_heads=max(cfg.n_heads, 1), kv_heads=max(cfg.kv_heads, 1),
            ffn=max(cfg.d_ff, 1), vocab=cfg.vocab),
        m_ms=0.0, m_token=1.0)
    return CostModel(coeffs)


class Engine:
    """A training/serving session on one cluster with one swappable
    parallelism strategy.

    >>> eng = Engine("internvl3-2b", strategy="dhp", reduced=True)
    >>> metrics = eng.train(steps=5, dataset="openvid", global_batch=8)

    `model` is an arch id from the registry or a ModelConfig. VLM
    configs are run in token-stream mode (vision tokens pre-counted in
    the SeqInfo lengths, LM decoder executed) — the convention the DHP
    loader/executor pair uses throughout.
    """

    def __init__(self, model: Union[str, ModelConfig],
                 cluster: Optional[ClusterSpec] = None, *,
                 strategy: Union[str, Strategy] = "dhp",
                 optimizer: Optional[Any] = None,
                 cost_model: Optional[CostModel] = None,
                 reduced: bool = False,
                 packed: Optional[bool] = None,
                 seed: int = 0):
        """`packed` forwards to DHPExecutor: the packed varlen execution
        path (default: on for attention families)."""
        cfg = get_config(model) if isinstance(model, str) else model
        if reduced:
            cfg = cfg.reduced()
        if cfg.family == "vlm":
            cfg = cfg.with_(family="dense", vlm=None)
        self.cfg = cfg
        self._packed = packed
        self.cluster = cluster or ClusterSpec.auto()
        self.cost_model = cost_model or demo_cost_model(cfg)
        self.strategy = (get_strategy(strategy)
                         if isinstance(strategy, str) else strategy)
        self.strategy.bind(self.cost_model, self.cluster.n_replicas,
                           self.cluster.mem_budget)
        self.seed = seed
        self._optimizer = optimizer
        self._state = None
        self._executor: Optional[DHPExecutor] = None
        self._apply_update = None
        self._step = 0
        self._prev_plan: Optional[ExecutionPlan] = None
        #: session-lifetime counters/gauges/histograms (obs.metrics);
        #: updated by every execute(), snapshot() at any point
        self.metrics = MetricsRegistry()
        #: per-group (predicted, measured, rank-slot) records feeding
        #: the run report; installed by train(trace=/report=)
        self._recorder: Optional[RunRecorder] = None
        #: the RunReport of the last traced/reported train() call
        self.last_report: Optional[RunReport] = None
        #: the loader train() last built/used — checkpointed so resume
        #: replays the exact remaining batch stream
        self.loader = None
        self._loader_state: Optional[dict] = None

    # -- lazy heavyweight pieces ----------------------------------------
    @property
    def executor(self) -> DHPExecutor:
        if self._executor is None:
            self._executor = DHPExecutor(self.cfg,
                                         pool=self.cluster.pool(),
                                         packed=self._packed)
        return self._executor

    @property
    def optimizer(self):
        if self._optimizer is None:
            from ..training.optimizer import AdamW
            self._optimizer = AdamW(lr=3e-4)
        return self._optimizer

    @property
    def state(self):
        if self._state is None:
            self._state = self.init_state(self.seed)
        return self._state

    @state.setter
    def state(self, value):
        self._state = value

    def init_state(self, seed: int = 0):
        import jax
        from ..models.model import init_params
        from ..training.train_step import TrainState
        params = init_params(jax.random.PRNGKey(seed), self.cfg)
        return TrainState(params=params,
                          opt=self.optimizer.init(params))

    # -- plan -----------------------------------------------------------
    def plan(self, batch: Batch) -> ExecutionPlan:
        """Plan one global batch with the session's strategy."""
        infos = batch.infos if isinstance(batch, RaggedBatch) else batch
        return self.strategy.plan(infos)

    # -- execute --------------------------------------------------------
    def execute(self, plan: ExecutionPlan, data: RaggedBatch, *,
                update: bool = True,
                measure: Optional[bool] = None) -> StepMetrics:
        """Run a plan on the cluster; optionally apply the optimizer
        update. `measure` forces per-group timing capture (defaults to
        whatever the strategy asks for — OracleStrategy wants it)."""
        import jax

        if measure is None:
            # an installed recorder needs per-group timings too (the
            # run report's imbalance/straggler/MAPE inputs)
            measure = (self.strategy.wants_measurement
                       or self._recorder is not None)
        # Group-reconfiguration delta vs the previously executed plan:
        # the pool consumes it (reused slots cost nothing, new/resized
        # slots are created) instead of re-deriving every group.
        if plan.delta is None:
            plan.delta = diff_plans(self._prev_plan, plan,
                                    self.cluster.n_replicas)
        self.executor.pool.reconfigure(plan.delta)
        self._prev_plan = plan
        timings: Optional[List[dict]] = [] if measure else None
        t0 = time.perf_counter()
        loss, grads = self.executor.run_plan(self.state.params, plan,
                                             data, timings=timings)
        if update:
            if self._apply_update is None:
                from ..training.train_step import TrainState
                opt = self.optimizer

                @jax.jit
                def apply_update(state, grads):
                    p, o = opt.update(grads, state.opt, state.params)
                    return TrainState(p, o)

                self._apply_update = apply_update
            self.state = self._apply_update(self.state, grads)
        step_time = time.perf_counter() - t0
        model_error = 0.0
        if timings:
            self.strategy.observe(plan, timings)
            model_error = step_model_error(plan, timings)
            if self._recorder is not None:
                self._recorder.record_step(self._step, plan, timings)
        mod_tokens: Dict[str, int] = {}
        for s in data.infos:
            spans = getattr(s, "spans", None)
            if spans:
                for sp in spans:
                    mod_tokens[sp.modality] = (
                        mod_tokens.get(sp.modality, 0) + sp.length)
            else:
                mod_tokens["text"] = mod_tokens.get("text", 0) + s.length
        metrics = StepMetrics(
            step=self._step,
            loss=float(loss),
            tokens=sum(g.tokens for mb in plan.micro_batches
                       for g in mb.groups),
            step_time_s=step_time,
            strategy=plan.strategy_name or self.strategy.name,
            schedule_ms=plan.schedule_ms,
            solver_ms=plan.solver_ms,
            stage_ms=dict(plan.stage_ms),
            degree_histogram=plan.degree_histogram,
            padding_efficiency=self.executor.last_run_stats.get(
                "padding_efficiency", 1.0),
            exe_misses=self.executor.last_run_stats.get("exe_misses", 0),
            plan_cache_hit=plan.from_cache,
            groups_reconfigured=plan.delta.n_reconfigured,
            modality_tokens=mod_tokens,
            allocate_us=plan.stage_ms.get("allocate", 0.0) * 1e3,
            replan_mode=plan.replan_mode,
            modality_loss=dict(self.executor.last_run_stats.get(
                "modality_loss", {})),
            model_error_pct=model_error,
            plan_cache=(dict(self.strategy.plan_cache.stats)
                        if self.strategy.plan_cache is not None else {}),
        )
        self._step += 1
        self._update_metrics(metrics, measured=bool(timings))
        return metrics

    def _update_metrics(self, m: StepMetrics, *, measured: bool) -> None:
        """Fold one step's signals into the session metrics registry."""
        reg = self.metrics
        reg.counter("train/steps").inc()
        reg.counter("train/tokens").inc(m.tokens)
        reg.counter("pool/exe_misses").inc(m.exe_misses)
        reg.counter("pool/groups_reconfigured").inc(
            m.groups_reconfigured)
        reg.counter("plan/steps_from_cache").inc(int(m.plan_cache_hit))
        reg.histogram("plan/schedule_ms").observe(m.schedule_ms)
        reg.histogram("plan/allocate_us").observe(m.allocate_us)
        reg.histogram("exec/step_time_s").observe(m.step_time_s)
        reg.histogram("exec/padding_efficiency").observe(
            m.padding_efficiency)
        if measured:
            reg.histogram("cost_model/error_pct").observe(
                m.model_error_pct)
        # cumulative cache/pool state lands as gauges under distinct
        # prefixes so they cannot collide with the per-step counters
        reg.update_from(m.plan_cache, "plan/cache_")
        reg.update_from(vars(self.executor.pool.stats), "pool/total_")

    # -- train: THE loop ------------------------------------------------
    def train(self, loader: Optional[Iterable[RaggedBatch]] = None, *,
              steps: int = 10, dataset: str = "openvid",
              global_batch: int = 8, max_tokens: int = 512,
              tokens_per_frame: int = 16,
              lookahead: Union[bool, int] = True,
              plan_log: Optional[List[ExecutionPlan]] = None,
              log=None,
              trace: Union[None, bool, str, Tracer] = None,
              report: Union[None, bool, str] = None
              ) -> List[StepMetrics]:
        """The single training driver: heterogeneous batches -> strategy
        plan -> executor. Every strategy (static baselines included)
        runs through this one loop.

        `lookahead=True` (default) runs the planner pipeline: a
        background host thread plans batch t+1 while devices execute
        batch t, and `StepMetrics.plan_overlap_ms` reports how much
        planning latency that hid. An int widens the window: batches
        t+1..t+k are enqueued to the planner thread, which solves them
        back-to-back sharing the scheduler's warm allocator state (the
        batched-lookahead contract — see docs/api.md "Planner
        performance"). `lookahead=False` is the synchronous baseline —
        plan, then execute, back to back.

        `plan_log`: pass a list to receive every executed ExecutionPlan
        (the `--save-plans` trace).

        `trace`: a path (Chrome trace-event JSON is saved there), True,
        or a Tracer instance — records the run's timeline: scheduler
        stages and the lookahead planner thread on host tracks, measured
        group execution on one track per simulated rank (load the file
        at https://ui.perfetto.dev). `report`: a path or True — builds
        the post-run analytics RunReport (per-wave imbalance, per-rank
        straggler scores, cost-model MAPE), kept on `self.last_report`
        and saved as JSON when a path is given. Either option switches
        execution to measuring mode (per-group synchronous timing), so
        the concurrent dispatch of disjoint groups is traded for
        observability — see docs/api.md "Observability"."""
        tracer: Optional[Tracer] = None
        trace_path: Optional[str] = None
        if trace is not None and trace is not False:
            if isinstance(trace, str):
                trace_path, tracer = trace, Tracer()
            elif trace is True:
                tracer = Tracer()
            else:
                tracer = trace
        observing = tracer is not None or bool(report)
        if observing:
            self._recorder = RunRecorder(self.cluster.n_replicas)
        history: List[StepMetrics] = []
        try:
            if tracer is not None:
                with tracing(tracer):
                    self._train_loop(loader, steps, dataset,
                                     global_batch, max_tokens,
                                     tokens_per_frame, lookahead,
                                     plan_log, log, history)
            else:
                self._train_loop(loader, steps, dataset, global_batch,
                                 max_tokens, tokens_per_frame,
                                 lookahead, plan_log, log, history)
        finally:
            if observing:
                self.last_report = build_report(
                    self._recorder, history,
                    metrics=self.metrics.snapshot())
                self._recorder = None
                if isinstance(report, str):
                    self.last_report.save(report)
            if trace_path is not None:
                tracer.save(trace_path)
        return history

    def _train_loop(self, loader, steps, dataset, global_batch,
                    max_tokens, tokens_per_frame, lookahead, plan_log,
                    log, history: List[StepMetrics]) -> None:
        if loader is None:
            loader = HeterogeneousLoader(
                dataset, global_batch, self.cfg.vocab, seed=self.seed,
                max_tokens=max_tokens, tokens_per_frame=tokens_per_frame)
        if self._loader_state is not None and hasattr(loader, "set_state"):
            # a checkpoint restore left a stream position to resume from
            loader.set_state(self._loader_state)
            self._loader_state = None
        self.loader = loader
        it: Iterator[RaggedBatch] = iter(loader)

        # lookahead depth: 0 = synchronous, k >= 1 = plans for batches
        # t+1..t+k kept in flight on the planner thread.
        depth = (1 if lookahead is True
                 else 0 if lookahead is False else max(0, int(lookahead)))
        try:
            data = next(it)
        except StopIteration:
            return
        n_fetched = 1
        if depth:
            self.strategy.prepare(data.infos)
        from collections import deque
        queue: "deque[RaggedBatch]" = deque()   # fetched, plan in flight
        for i in range(steps):
            if depth:
                plan = self.strategy.collect()
                overlap = max(
                    0.0, plan.schedule_ms - self.strategy.last_wait_ms)
            else:
                plan = self.strategy.plan(data.infos)
                overlap = 0.0
            # Top up the prefetch window — but only with batches that
            # WILL execute (n_fetched < steps): consuming a batch (or
            # popping a replay plan) that never runs would desync
            # resumable loaders and ReplayStrategy's cursor.
            while n_fetched < steps and len(queue) < max(depth, 1):
                try:
                    nxt = next(it)
                except StopIteration:
                    break
                queue.append(nxt)
                n_fetched += 1
                if depth:
                    self.strategy.prepare(nxt.infos)  # overlap planning
            metrics = self.execute(plan, data)
            metrics.plan_overlap_ms = overlap
            if plan_log is not None:
                plan_log.append(plan)
            history.append(metrics)
            if log is not None:
                log(metrics.summary())
            if not queue:
                break
            data = queue.popleft()

    # -- serve ----------------------------------------------------------
    def serve(self, prompts=None, *, batch: int = 8,
              prompt_len: int = 96, gen_tokens: int = 32,
              cache_len: Optional[int] = None):
        """Batched prefill + greedy decode via serving/serve_step.

        `prompts`: [B, S] int32 token ids (random ids drawn when None).
        Attention families (dense/moe/vlm) prefill a KV cache;
        ssm/recurrent/hybrid families start from a fresh state cache and
        audio additionally prefills the encoder cross-KV from synthetic
        frames — the same per-family routing the pre-API quickstart did.
        Returns (decoded [B, gen_tokens] tokens, dict of timings)."""
        import jax
        import jax.numpy as jnp

        from ..models.model import (init_cache, prefill,
                                    prefill_cross_kv)
        from ..serving.serve_step import greedy_generate, make_serve_step

        if prompts is None:
            prompts = jax.random.randint(
                jax.random.PRNGKey(self.seed + 1), (batch, prompt_len),
                0, self.cfg.vocab)
        prompts = jnp.asarray(prompts)
        batch, prompt_len = prompts.shape
        cache_len = cache_len or prompt_len + gen_tokens

        t0 = time.perf_counter()
        if self.cfg.family in ("dense", "moe", "vlm"):
            logits, cache = prefill(self.state.params, self.cfg,
                                    {"tokens": prompts},
                                    cache_len=cache_len)
            first = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        else:
            cache = init_cache(self.cfg, batch, cache_len)
            if self.cfg.family == "audio":
                frames = jax.random.normal(
                    jax.random.PRNGKey(self.seed + 2),
                    (batch, self.cfg.encdec.n_audio_frames,
                     self.cfg.d_model))
                cache = prefill_cross_kv(self.state.params, self.cfg,
                                         frames, cache)
            first = prompts[:, -1].astype(jnp.int32)
        t_prefill = time.perf_counter() - t0

        # The decode step lives in the cluster's shared executable pool
        # (same cache the training groups use), keyed on the shapes that
        # force recompilation — repeat serve calls skip the jit.
        step, step_miss = self.cluster.pool().executable_for(
            ("serve", self.cfg.arch_id, self.cfg.family, batch,
             cache_len),
            lambda: jax.jit(make_serve_step(self.cfg)))
        t0 = time.perf_counter()
        out, cache = greedy_generate(self.state.params, self.cfg, cache,
                                     first, gen_tokens, step=step)
        t_decode = time.perf_counter() - t0
        report = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "ms_per_token": t_decode / max(gen_tokens, 1) * 1e3,
            "batch": batch,
            "prompt_len": prompt_len,
            "exe_miss": step_miss,
        }
        return out, report

    # -- serving ---------------------------------------------------------
    def serving(self, *, slots: int = 4, prefill_chunk: int = 128,
                cache_len: Optional[int] = None, block_size: int = 16,
                n_blocks: Optional[int] = None, strategy: str = "dhp"):
        """The continuous-batching runtime over this engine's model and
        cluster (serving/runtime.py): paged KV slots, DHP-planned
        chunked prefill, iteration-level batching. `serve()` below stays
        the one-shot fixed-batch path."""
        from ..serving.runtime import ServingEngine
        return ServingEngine(
            self.cfg, self.state.params, self.cluster, self.cost_model,
            slots=slots, cache_len=cache_len, block_size=block_size,
            n_blocks=n_blocks, prefill_chunk=prefill_chunk,
            strategy=strategy, seed=self.seed)

    # -- checkpointing ---------------------------------------------------
    def save_checkpoint(self, path: str) -> None:
        """Full train-state snapshot: params + optimizer moments + step
        counter + (when train() ran with a resumable loader) the data
        stream position — everything a bit-identical resume needs."""
        from ..training.checkpoint import save
        meta: Dict[str, Any] = {"format": 2, "step": self._step}
        if self.loader is not None and hasattr(self.loader, "state"):
            meta["loader"] = self.loader.state()
        save(path, {"params": self.state.params, "opt": self.state.opt},
             meta=meta)

    def load_checkpoint(self, path: str) -> None:
        from ..training.checkpoint import load_meta, restore
        meta = load_meta(path)
        if meta is None:
            # pre-format-2 checkpoint: params only, no meta blob
            self.state = self.state._replace(
                params=restore(path, self.state.params))
            return
        tree = restore(path, {"params": self.state.params,
                              "opt": self.state.opt})
        self.state = self.state._replace(params=tree["params"],
                                         opt=tree["opt"])
        self._step = int(meta.get("step", self._step))
        self._loader_state = meta.get("loader")

    def close(self) -> None:
        self.strategy.close()


#: `Session` is the facade name from the API docs; `Engine` the original.
Session = Engine
