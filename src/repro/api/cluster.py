"""ClusterSpec — the device-topology half of a Session.

One object owns everything that is *per-cluster* rather than per-model:
the flat device list, the static model (TP) axis width, the per-rank
activation memory budget the planners schedule against, the bandwidth
topology for Eq. 9, and the GroupPool of cached sub-meshes + compiled
executables that every engine on this cluster shares.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

from ..core.cost_model import Hardware
from ..core.group_pool import GroupPool


@dataclasses.dataclass
class ClusterSpec:
    """Devices + model axis + GroupPool ownership.

    `devices=None` resolves to `jax.devices()` on first use (kept lazy so
    constructing a spec never initialises the jax backend — the dry-run
    and tests depend on controlling XLA_FLAGS before first touch).

    `mem_budget` is the per-rank activation budget E of Eq. 3. Its unit
    matches the cost model's `m_token`: bytes for profiled/roofline
    coefficients, plain tokens for the CPU-demo calibration.

    `bucketing` picks the GroupPool's padding-bucket ladder
    ("pow2" | "geometric" | "mult256", or a callable n -> bucket):
    fewer rungs = fewer XLA compilations, more rungs = less padding
    waste. `max_executables` LRU-caps the pool's compiled-executable
    cache so long heterogeneous runs can't grow host memory unboundedly.
    """

    devices: Optional[Sequence[Any]] = None
    model_axis: int = 1
    mem_budget: float = 1024.0
    hardware: Hardware = dataclasses.field(default_factory=Hardware)
    bucketing: Any = "pow2"
    max_executables: Optional[int] = None
    _pool: Optional[GroupPool] = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- resolution -----------------------------------------------------
    def resolved_devices(self) -> List[Any]:
        if self.devices is None:
            import jax
            self.devices = list(jax.devices())
        return list(self.devices)

    @property
    def n_devices(self) -> int:
        return len(self.resolved_devices())

    @property
    def n_replicas(self) -> int:
        """Number of CP-schedulable ranks (device count / model axis) —
        the N the planners allocate over."""
        return self.n_devices // self.model_axis

    # -- owned resources ------------------------------------------------
    def pool(self) -> GroupPool:
        """The cluster's GroupPool (created once, shared by engines)."""
        if self._pool is None:
            self._pool = GroupPool(self.resolved_devices(),
                                   self.model_axis,
                                   bucket_fn=self.bucketing,
                                   max_executables=self.max_executables)
        return self._pool

    def decode_shape(self, n_active: int, context_len: int, *,
                     min_slots: int = 2) -> tuple:
        """Bucket a serving decode shape: (slot count, cache length).

        Slot counts ride a pow2 ladder from `min_slots`, cache lengths
        the pool's configured padding ladder — the serving analogue of
        the training bucketing, so the slot-vmapped decode step (and the
        slot-writer) compile once per rung instead of once per trace.
        """
        from ..core.group_pool import pow2_bucket
        slots = pow2_bucket(max(int(n_active), 1), minimum=min_slots)
        return slots, self.pool().bucket(int(context_len))

    def mesh(self):
        """Full-cluster (data, model) demo mesh for static pjit paths."""
        import jax
        devs = self.resolved_devices()
        return jax.make_mesh(
            (self.n_replicas, self.model_axis), ("data", "model"),
            devices=devs[:self.n_replicas * self.model_axis])

    # -- constructors ----------------------------------------------------
    @classmethod
    def auto(cls, *, model_axis: int = 1,
             mem_budget: float = 1024.0,
             hardware: Optional[Hardware] = None,
             bucketing: Any = "pow2",
             max_executables: Optional[int] = None) -> "ClusterSpec":
        """Spec over every visible device (the common entry point)."""
        return cls(devices=None, model_axis=model_axis,
                   mem_budget=mem_budget,
                   hardware=hardware or Hardware(),
                   bucketing=bucketing,
                   max_executables=max_executables)
