"""Heterogeneous multimodal data pipeline (synthetic, deterministic).

Generates the kind of batches DHP schedules: variable-length multimodal
sequences drawn from the paper's dataset distributions (core/
distributions.py), each a (vision-tokens + text-tokens) pair. Provides:

  * `HeterogeneousLoader` — yields global batches of SeqInfo + token
    arrays, the DHP scheduler's input;
  * `padded_batch(...)` — pads a set of sequences to a bucket for one
    CP-group micro-step (tokens, labels, mask, positions);
  * `synthetic_batch(cfg, shape)` — fixed-shape batch for dry-runs /
    benchmarks / examples.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence as Seq

import numpy as np

from ..configs.base import InputShape, ModelConfig
from ..core.cost_model import SeqInfo
from ..core.distributions import sample_batch
from ..core.packing import fill_loss_row, fill_modality_row


@dataclasses.dataclass
class RaggedBatch:
    infos: List[SeqInfo]
    tokens: List[np.ndarray]       # per-sequence token ids (int32)

    def by_id(self, seq_id: int) -> np.ndarray:
        return self.tokens[seq_id]

    def spans_by_id(self) -> Dict[int, tuple]:
        """seq_id -> ModalitySpan tuple (only span-bearing sequences)."""
        return {s.seq_id: s.spans for s in self.infos
                if getattr(s, "spans", None)}


class HeterogeneousLoader:
    """Iterator of ragged global batches from a video-length distribution.

    Resumable: `state()` / `set_state()` snapshot and restore the exact
    stream position (rng bit-generator state + batch index), so a
    lookahead planner prefetching batch t+1 and a checkpoint-restored
    run both see the SAME sequence of batches the original run did —
    the precondition for `--replay-plans` being bit-identical.
    """

    def __init__(self, dataset: str, gbs: int, vocab: int, *,
                 seed: int = 0, max_tokens: Optional[int] = None,
                 tokens_per_frame: int = 256):
        self.dataset = dataset
        self.gbs = gbs
        self.vocab = vocab
        self.max_tokens = max_tokens
        self.tokens_per_frame = tokens_per_frame
        self.rng = np.random.default_rng(seed)
        self.batch_index = 0

    def __iter__(self) -> Iterator[RaggedBatch]:
        return self

    def __next__(self) -> RaggedBatch:
        infos = sample_batch(self.dataset, self.gbs, self.rng,
                             max_tokens=self.max_tokens,
                             tokens_per_frame=self.tokens_per_frame)
        toks = [self.rng.integers(0, self.vocab, size=s.length,
                                  dtype=np.int32) for s in infos]
        self.batch_index += 1
        return RaggedBatch(infos=infos, tokens=toks)

    # -- resumability ----------------------------------------------------
    def state(self) -> Dict:
        """JSON-serializable snapshot of the stream position."""
        return {"batch_index": self.batch_index,
                "rng_state": self.rng.bit_generator.state}

    def set_state(self, state: Dict) -> None:
        """Restore a `state()` snapshot; the next `__next__` yields the
        same batch it would have in the original run."""
        self.rng.bit_generator.state = state["rng_state"]
        self.batch_index = int(state["batch_index"])


def padded_batch(seqs: Seq[np.ndarray], bucket: int,
                 pad_id: int = 0,
                 spans: Optional[Seq] = None) -> Dict[str, np.ndarray]:
    """Pad ragged sequences to [n, bucket]: tokens/labels/mask/positions
    + modality_ids / loss_mask / modality_classes when `spans` carries
    any layout (per-row bidirectional-span table, -1 = causal/pad;
    `spans` is a per-sequence list of ModalitySpan tuples, entries may
    be None). Same mixed-mask and loss-mask semantics — and the same
    emit-only-when-present rule — as the packed path, so packed and
    per-sequence execution stay numerically identical and pure-causal
    batches skip the span-masked attention path entirely."""
    n = len(seqs)
    if spans is not None and not any(spans):
        spans = None
    tokens = np.full((n, bucket), pad_id, np.int32)
    mask = np.zeros((n, bucket), np.float32)
    modality_ids = (np.full((n, bucket), -1, np.int32)
                    if spans is not None else None)
    classes = (np.full((n, bucket), -1, np.int32)
               if spans is not None else None)
    loss_mask = np.zeros((n, bucket), np.float32) \
        if spans is not None else None
    for i, s in enumerate(seqs):
        L = min(len(s), bucket)
        tokens[i, :L] = s[:L]
        mask[i, :L] = 1.0
        mask[i, L - 1] = 0.0   # last valid token has no next-token label
        if modality_ids is not None:
            fill_modality_row(modality_ids[i], spans[i], 0, L, 0)
            loss_mask[i] = mask[i]
            fill_loss_row(classes[i], loss_mask[i], spans[i], 0, L)
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = pad_id
    positions = np.tile(np.arange(bucket, dtype=np.int32), (n, 1))
    batch = {"tokens": tokens, "labels": labels, "mask": mask,
             "positions": positions}
    if modality_ids is not None:
        batch["modality_ids"] = modality_ids
        batch["loss_mask"] = loss_mask
        batch["modality_classes"] = classes
    return batch


def synthetic_batch(cfg: ModelConfig, shape: InputShape,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Fixed-shape (global_batch, seq_len) batch matching input_specs."""
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
        "labels": rng.integers(0, cfg.vocab, (B, S)).astype(np.int32),
    }
    if cfg.family == "vlm":
        P = max(1, int(S * cfg.vlm.patches_per_seq_frac))
        batch["patch_embeds"] = rng.normal(
            0, 1, (B, P, cfg.vlm.vision_dim)).astype(np.float32)
        pos = np.tile(np.arange(P, dtype=np.int32), (B, 1))
        batch["patch_pos"] = pos
    if cfg.family == "audio":
        F = cfg.encdec.n_audio_frames
        batch["frames"] = rng.normal(0, 1, (B, F, cfg.d_model)).astype(
            np.float32)
    return batch
