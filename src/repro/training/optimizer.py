"""AdamW + schedules, pure JAX (no optax dependency).

`state_dtype="bfloat16"` stores first/second moments in bf16 — the
memory mode that lets llama3-405b fit the single-pod HBM budget (see
DESIGN.md hardware-adaptation notes); fp32 is the default elsewhere.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str | None = None      # None -> match param dtype

    def _sdtype(self, p):
        if self.state_dtype is None:
            return jnp.float32
        return {"float32": jnp.float32,
                "bfloat16": jnp.bfloat16}[self.state_dtype]

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self._sdtype(p))
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:   # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v)


def cosine_schedule(peak: float, warmup: int, total: int,
                    floor_frac: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak * (floor_frac + (1 - floor_frac)
                      * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale
                                   ).astype(l.dtype), tree), norm
