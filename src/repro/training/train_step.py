"""Loss + train step factory."""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import forward
from .optimizer import AdamW, AdamWState, clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token NLL. logits [B,S,V] fp32-softmaxed, labels [B,S].

    The gold logit is extracted with a one-hot multiply-reduce rather
    than `take_along_axis`: a vocab-dim gather forces GSPMD to all-gather
    the full [B,S,V] logits when V is sharded over the model axis,
    whereas iota-compare-select-reduce stays vocab-sharded and fuses
    (§Perf iteration P4 — 34 GB/device of logits traffic removed)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Any]):
    logits, aux = forward(params, cfg, batch)
    aux_coef = cfg.moe.aux_loss_coef if cfg.moe else 0.0
    loss = cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss + aux_coef * aux / max(cfg.n_layers, 1), (loss, aux)


def make_train_step(cfg: ModelConfig, opt: AdamW, *,
                    grad_clip: float = 1.0, dp_axis: Optional[str] = None,
                    accum_steps: int = 1, grad_constraint=None):
    """Returns train_step(state, batch) -> (state, metrics).

    `dp_axis` psums grads (used inside shard_map CP/DP groups); under
    plain pjit the partitioner inserts the reduction automatically.
    `accum_steps > 1` splits the global batch along its leading axis into
    micro-batches processed by a lax.scan (gradient accumulation): the
    peak activation footprint shrinks by ~accum_steps at the cost of one
    extra grads-sized buffer — the standard fit for llama3-405b-class
    training steps (see DESIGN.md / §Perf).

    `grad_constraint`: optional grads_tree -> grads_tree hook applying
    `with_sharding_constraint`s to the accumulator carry. Constraining
    the carry to the FSDP param sharding makes GSPMD reduce-scatter each
    micro-batch's gradient instead of all-reducing it and carrying a
    replicated f32 accumulator (§Perf iteration L1: 4× less gradient
    collective traffic and 1/data_ways the accumulator memory).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def one(params, batch):
        (total, (loss, aux)), grads = grad_fn(params, cfg, batch)
        return total, loss, aux, grads

    def train_step(state: TrainState, batch):
        if accum_steps == 1:
            total, loss, aux, grads = one(state.params, batch)
            if grad_constraint is not None:
                grads = grad_constraint(grads)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape(accum_steps, b // accum_steps,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            if grad_constraint is not None:
                zero_g = grad_constraint(zero_g)
            zero_m = (jnp.zeros((), jnp.float32),) * 3

            def body(carry, mb):
                (t, l, a), g = carry
                ti, li, ai, gi = one(state.params, mb)
                g = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g, gi)
                if grad_constraint is not None:
                    g = grad_constraint(g)
                return ((t + ti, l + li, a + ai), g), None

            ((total, loss, aux), grads), _ = jax.lax.scan(
                body, (zero_m, zero_g), micro)
            total, loss, aux = (x / accum_steps for x in
                                (total, loss, aux))
            grads = jax.tree.map(lambda g_, p: (g_ / accum_steps).astype(
                p.dtype), grads, state.params)
        if dp_axis is not None:
            grads = jax.lax.pmean(grads, dp_axis)
            loss = jax.lax.pmean(loss, dp_axis)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm,
                   "total": total}
        return TrainState(params, opt_state), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, (loss, _aux) = loss_fn(params, cfg, batch)
        return loss
    return eval_step
