"""Checkpointing: flat .npz save/restore of arbitrary pytrees.

`save`/`restore` flatten any pytree (dicts, NamedTuples such as
TrainState/AdamWState) into named npz entries. A JSON `meta` blob rides
along under a reserved key for non-array state — the training step
counter and the data-loader stream position that make a restored run
continue bit-identically.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"
META_KEY = "__meta_json__"


def _path_name(p) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (NamedTuple
    # fields) -> .name
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[SEP.join(_path_name(p) for p in path)] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, meta: Optional[dict] = None) -> None:
    flat = _flatten(tree)
    if meta is not None:
        flat[META_KEY] = np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8)
    tmp = path + ".tmp"
    np.savez(tmp, **flat)
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def load_meta(path: str) -> Optional[dict]:
    """The JSON meta blob of a checkpoint, or None (old format)."""
    data = np.load(path)
    if META_KEY not in data.files:
        return None
    return json.loads(bytes(data[META_KEY].tobytes()).decode())


def restore(path: str, like: Any) -> Any:
    """Restores into the structure (and dtypes) of `like`."""
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = SEP.join(_path_name(q) for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


