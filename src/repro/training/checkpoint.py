"""Checkpointing: flat .npz save/restore of arbitrary pytrees."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "::"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like: Any) -> Any:
    """Restores into the structure (and dtypes) of `like`."""
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = SEP.join(
            str(q.key) if hasattr(q, "key") else str(q.idx) for q in p)
        arr = data[key]
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)
