"""Dataset profiles — the ONE place the paper's evaluated datasets are
described (Fig. 1 duration statistics + modality-layout conventions).

Both the training-side length/span sampler (core/distributions.py) and
the serving trace generator (serving/trace.py) draw from this table;
previously each kept its own copy of the lognormal parameters.

Layouts (how a clip's tokens are arranged into modality spans):
  * "interleaved"  — per-frame bidirectional vision blocks interleaved
                     with causal text (OpenVid / InternVid style
                     frame-caption streams);
  * "audio_prefix" — one bidirectional audio window up front, followed
                     by the causal caption (MSRVTT-style transcription).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

LAYOUT_INTERLEAVED = "interleaved"
LAYOUT_AUDIO_PREFIX = "audio_prefix"


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    """Duration distribution (truncated lognormal, Fig. 1) plus the
    modality-layout convention of one evaluated dataset."""

    name: str
    mu: float        # lognormal mean of log-duration (seconds)
    sigma: float     # lognormal sigma — the long-tail knob
    min_s: float
    max_s: float
    layout: str = LAYOUT_INTERLEAVED
    modality: str = "vision"        # the bidirectional modality
    fps: float = 1.0
    tokens_per_frame: int = 256
    text_tokens: int = 128


MSRVTT = DatasetProfile("msrvtt", mu=math.log(15.0), sigma=0.35,
                        min_s=10, max_s=32,
                        layout=LAYOUT_AUDIO_PREFIX, modality="audio")
INTERNVID = DatasetProfile("internvid", mu=math.log(6.0), sigma=0.8,
                           min_s=1, max_s=128)
OPENVID = DatasetProfile("openvid", mu=math.log(5.0), sigma=1.25,
                         min_s=1, max_s=512)

PROFILES = {d.name: d for d in (MSRVTT, INTERNVID, OPENVID)}


def get_profile(dataset: Union[str, DatasetProfile]) -> DatasetProfile:
    if isinstance(dataset, DatasetProfile):
        return dataset
    if dataset not in PROFILES:
        raise KeyError(
            f"unknown dataset {dataset!r}; known: {sorted(PROFILES)}")
    return PROFILES[dataset]
