"""Dataset profiles — the ONE place the paper's evaluated datasets are
described (Fig. 1 duration statistics + modality-layout conventions).

Both the training-side length/span sampler (core/distributions.py) and
the serving trace generator (serving/trace.py) draw from this table;
previously each kept its own copy of the lognormal parameters.

Layouts (how a clip's tokens are arranged into modality spans):
  * "interleaved"  — per-frame bidirectional vision blocks interleaved
                     with causal text (OpenVid / InternVid style
                     frame-caption streams);
  * "audio_prefix" — one bidirectional audio window up front, followed
                     by the causal caption (MSRVTT-style transcription);
  * "prefix"       — same geometry for any modality: one bidirectional
                     block then causal text (image-QA's images-then-
                     question convention).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Union

LAYOUT_INTERLEAVED = "interleaved"
LAYOUT_AUDIO_PREFIX = "audio_prefix"
LAYOUT_PREFIX = "prefix"


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    """Duration distribution (truncated lognormal, Fig. 1) plus the
    modality-layout convention of one evaluated dataset."""

    name: str
    mu: float        # lognormal mean of log-duration (seconds)
    sigma: float     # lognormal sigma — the long-tail knob
    min_s: float
    max_s: float
    layout: str = LAYOUT_INTERLEAVED
    modality: str = "vision"        # the bidirectional modality
    fps: float = 1.0
    tokens_per_frame: int = 256
    text_tokens: int = 128


MSRVTT = DatasetProfile("msrvtt", mu=math.log(15.0), sigma=0.35,
                        min_s=10, max_s=32,
                        layout=LAYOUT_AUDIO_PREFIX, modality="audio")
INTERNVID = DatasetProfile("internvid", mu=math.log(6.0), sigma=0.8,
                           min_s=1, max_s=128)
OPENVID = DatasetProfile("openvid", mu=math.log(5.0), sigma=1.25,
                         min_s=1, max_s=512)
# Image-QA (LLaVA-Instruct / VQAv2-style): "duration" counts IMAGES —
# mostly single-image turns, occasionally multi-image (<= 4). Each image
# is one bidirectional block of 576 tokens (CLIP ViT-L/14 @ 336px =
# 24x24 patches, the LLaVA-1.5 projector output); ~80 causal text
# tokens of question + answer. The near-degenerate length spread is the
# point: DHP's win case is heterogeneity, and a homogeneous dataset
# must not regress vs static parallelism.
IMAGEQA = DatasetProfile("imageqa", mu=math.log(1.0), sigma=0.4,
                         min_s=1, max_s=4, layout=LAYOUT_PREFIX,
                         modality="vision", fps=1.0,
                         tokens_per_frame=576, text_tokens=80)
# Long-form speech recognition (LibriLight / earnings-call style):
# clips of 30 s .. 15 min, median ~3 min. 25 audio tokens per second
# (Whisper-style encoder: 50 frame/s mel front-end, 2x conv
# downsampling), transcript ~400 causal text tokens. The heavy upper
# tail (sigma 0.7 over minutes-long durations) stresses the allocator's
# high-d_min path the video sets never reach.
LONGAUDIO = DatasetProfile("longaudio", mu=math.log(180.0), sigma=0.7,
                           min_s=30, max_s=900,
                           layout=LAYOUT_AUDIO_PREFIX, modality="audio",
                           fps=1.0, tokens_per_frame=25,
                           text_tokens=400)

PROFILES = {d.name: d for d in (MSRVTT, INTERNVID, OPENVID,
                                IMAGEQA, LONGAUDIO)}


def get_profile(dataset: Union[str, DatasetProfile]) -> DatasetProfile:
    if isinstance(dataset, DatasetProfile):
        return dataset
    if dataset not in PROFILES:
        raise KeyError(
            f"unknown dataset {dataset!r}; known: {sorted(PROFILES)}")
    return PROFILES[dataset]
