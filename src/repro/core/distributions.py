"""Synthetic multimodal sequence-length distributions (paper Fig. 1).

The paper evaluates on MSRVTT, InternVid, and OpenVid; their duration
histograms (Fig. 1) show: MSRVTT — clips 10-30 s, fairly uniform;
InternVid — broad, most < 8 s with a tail; OpenVid — extreme long tail
(most < 8 s, a few > 64 s). We model durations with truncated lognormals
calibrated to those summaries and convert to token counts:

  tokens = duration * fps * tokens_per_frame  (vision, full attention)
         + text_tokens                        (caption, causal)

eta (Eq. 8's mask-efficiency factor) is the vision-token fraction: a clip
whose tokens are mostly full-attention vision tokens approaches eta=1.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .cost_model import SeqInfo


@dataclasses.dataclass(frozen=True)
class VideoDataset:
    name: str
    mu: float        # lognormal mean of log-duration (seconds)
    sigma: float     # lognormal sigma — the long-tail knob
    min_s: float
    max_s: float


MSRVTT = VideoDataset("msrvtt", mu=np.log(15.0), sigma=0.35, min_s=10, max_s=32)
INTERNVID = VideoDataset("internvid", mu=np.log(6.0), sigma=0.8, min_s=1, max_s=128)
OPENVID = VideoDataset("openvid", mu=np.log(5.0), sigma=1.25, min_s=1, max_s=512)

DATASETS = {d.name: d for d in (MSRVTT, INTERNVID, OPENVID)}


def sample_batch(
    dataset: str | VideoDataset,
    n: int,
    rng: np.random.Generator,
    *,
    fps: float = 1.0,
    tokens_per_frame: int = 256,
    text_tokens: int = 128,
    max_tokens: int | None = None,
) -> List[SeqInfo]:
    """Draw a global batch of n multimodal sequences."""
    ds = DATASETS[dataset] if isinstance(dataset, str) else dataset
    dur = rng.lognormal(ds.mu, ds.sigma, size=n)
    dur = np.clip(dur, ds.min_s, ds.max_s)
    out: List[SeqInfo] = []
    for i, t in enumerate(dur):
        vis = int(t * fps) * tokens_per_frame
        total = vis + text_tokens
        if max_tokens is not None:
            total = min(total, max_tokens)
            vis = min(vis, total - 1)
        eta = vis / total  # fraction of full-attention tokens
        out.append(SeqInfo(length=int(total), eta=float(eta), seq_id=i))
    return out
