"""Synthetic multimodal sequence sampling (paper Fig. 1).

Duration statistics live in core/dataset_profiles.py (shared with the
serving trace generator); this module turns sampled durations into
STRUCTURED multimodal sequences:

  tokens = duration * fps * tokens_per_frame  (vision, bidirectional)
         + text_tokens                        (caption, causal)

`sample_mm_batch` is the first-class sampler: it lays the tokens out as
`ModalitySpan`s per the dataset's layout convention — interleaved
frame/text blocks for OpenVid/InternVid, an audio-prefix window for
MSRVTT — and returns `MMSequence`s. Eq. 8's eta is DERIVED from that
span geometry (`spans_eta`), replacing the old vision-token-fraction
scalar hack. `sample_batch` is the backward-compatible view returning
the `SeqInfo`s (spans attached), with the exact length distribution the
scalar sampler produced.
"""
from __future__ import annotations

from typing import List, Optional, Union

import numpy as np

from .cost_model import (ATTN_BIDIRECTIONAL, ATTN_CAUSAL, MMSequence,
                         ModalitySpan, SeqInfo)
from .dataset_profiles import (LAYOUT_AUDIO_PREFIX, LAYOUT_INTERLEAVED,
                               LAYOUT_PREFIX, INTERNVID, MSRVTT, OPENVID,
                               PROFILES, DatasetProfile, get_profile)

#: legacy aliases — the tables moved to core/dataset_profiles.py
VideoDataset = DatasetProfile
DATASETS = PROFILES


def _layout_spans(profile: DatasetProfile, vis: int, text: int,
                  tokens_per_frame: int) -> tuple:
    """Arrange `vis` bidirectional + `text` causal tokens per the
    dataset's layout convention. Always ends on a causal span when any
    text exists (the trailing caption), so next-token prediction has a
    causal tail."""
    spans: List[ModalitySpan] = []
    start = 0

    def add(mod: str, ln: int, attn: str):
        nonlocal start
        if ln > 0:
            spans.append(ModalitySpan(mod, start, ln, attn))
            start += ln

    if (profile.layout in (LAYOUT_AUDIO_PREFIX, LAYOUT_PREFIX)
            or vis == 0 or text == 0):
        add(profile.modality, vis, ATTN_BIDIRECTIONAL)
        add("text", text, ATTN_CAUSAL)
        return tuple(spans)
    assert profile.layout == LAYOUT_INTERLEAVED, profile.layout
    frames: List[int] = []
    left = vis
    while left > 0:
        m = min(tokens_per_frame, left)
        frames.append(m)
        left -= m
    # text split across the k+1 slots around the frames; the remainder
    # lands on the LAST slot so the stream ends with the caption
    base, rem = divmod(text, len(frames) + 1)
    for f in frames:
        add("text", base, ATTN_CAUSAL)
        add(profile.modality, f, ATTN_BIDIRECTIONAL)
    add("text", base + rem, ATTN_CAUSAL)
    return tuple(spans)


def sample_mm_batch(
    dataset: Union[str, DatasetProfile],
    n: int,
    rng: np.random.Generator,
    *,
    fps: Optional[float] = None,
    tokens_per_frame: Optional[int] = None,
    text_tokens: Optional[int] = None,
    max_tokens: Optional[int] = None,
) -> List[MMSequence]:
    """Draw a global batch of n structured multimodal sequences."""
    ds = get_profile(dataset)
    fps = ds.fps if fps is None else fps
    tokens_per_frame = (ds.tokens_per_frame if tokens_per_frame is None
                        else tokens_per_frame)
    text_tokens = ds.text_tokens if text_tokens is None else text_tokens
    dur = rng.lognormal(ds.mu, ds.sigma, size=n)
    dur = np.clip(dur, ds.min_s, ds.max_s)
    out: List[MMSequence] = []
    for i, t in enumerate(dur):
        vis = int(t * fps) * tokens_per_frame
        total = vis + text_tokens
        if max_tokens is not None:
            total = min(total, max_tokens)
            vis = min(vis, total - 1)
        spans = _layout_spans(ds, vis, total - vis, tokens_per_frame)
        out.append(MMSequence(spans=spans, seq_id=i))
    return out


def sample_batch(
    dataset: Union[str, DatasetProfile],
    n: int,
    rng: np.random.Generator,
    *,
    fps: Optional[float] = None,
    tokens_per_frame: Optional[int] = None,
    text_tokens: Optional[int] = None,
    max_tokens: Optional[int] = None,
) -> List[SeqInfo]:
    """Backward-compatible view of `sample_mm_batch`: the same batch as
    SeqInfos (spans attached, eta derived from the span geometry)."""
    return [m.seq_info for m in sample_mm_batch(
        dataset, n, rng, fps=fps, tokens_per_frame=tokens_per_frame,
        text_tokens=text_tokens, max_tokens=max_tokens)]
