"""Stage 1 — Memory-aware Sequence Packing via Best-Fit Decreasing (§4.3).

Transforms K heterogeneous sequences into K' <= K *atomic groups*.
Sequences are sorted by descending memory requirement; each sequence
either best-fits into an existing bin's headroom or opens a new bin with
capacity d_min * E_act where d_min = ceil(M(s)/E_act) (its minimum CP
degree under the per-rank activation budget E_act = E - M_ms).

Each atomic group is subsequently treated as ONE scheduling unit by the
2D-DP allocator; this both shrinks the DP's decision-variable count and
avoids the redundant-communication pathology of spreading many short
sequences across a wide CP group.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence as Seq, Tuple

import numpy as np

from .cost_model import ATTN_BIDIRECTIONAL, CostModel, SeqInfo

#: Label-modality classes reported in per-modality loss telemetry.
#: Fixed and ordered so the device-side per-class reduction has a
#: static shape; unknown modalities fold into "other".
MODALITY_CLASSES = ("text", "vision", "audio", "other")


def modality_class(name: str) -> int:
    try:
        return MODALITY_CLASSES.index(name)
    except ValueError:
        return len(MODALITY_CLASSES) - 1


@dataclasses.dataclass
class AtomicGroup:
    """A bin of sequences schedulable as one unit on >= d_min ranks."""

    seqs: List[SeqInfo]
    d_min: int               # minimum CP degree to satisfy Eq. (3)
    capacity: float          # d_min * E_act (bytes)
    used: float              # activation bytes currently packed

    @property
    def headroom(self) -> float:
        return self.capacity - self.used

    @property
    def total_tokens(self) -> int:
        return sum(s.length for s in self.seqs)


def pack_sequences(
    seqs: Seq[SeqInfo],
    cost_model: CostModel,
    budget: float,
    *,
    max_degree: int | None = None,
    balance_over: int | None = None,
) -> List[AtomicGroup]:
    """Best-Fit-Decreasing memory-aware packing (paper §4.3 Stage 1).

    Args:
      seqs: the micro-batch B of K sequences.
      cost_model: supplies M_token / M_ms (Eq. 7).
      budget: per-rank memory budget E in bytes (Eq. 3).
      max_degree: optional cap on d_min (e.g. the rank count N).
      balance_over: BEYOND-PAPER refinement — when set to the rank count
        N, bin capacity is clipped to ~total/N so low memory pressure
        still yields >= N atomic groups. The paper's capacity d_min*E is
        memory-driven only; with K' << N groups the DP has no freedom
        left and DHP can lose to plain round-robin DP (observed in the
        Fig.-5 8-rank point). Memory feasibility (Eq. 3) is unaffected:
        the clip only ever SHRINKS bins.

    Returns K' atomic groups, each with its minimum CP degree.
    """
    c = cost_model.coeffs
    e_act = budget - c.m_ms
    if e_act <= 0:
        raise ValueError("memory budget smaller than model states")

    order = sorted(seqs, key=lambda s: s.length * c.m_token, reverse=True)
    cap_clip = float("inf")
    if balance_over:
        total = sum(s.length for s in seqs) * c.m_token
        biggest = max((s.length for s in seqs), default=0) * c.m_token
        cap_clip = max(total / balance_over, biggest)

    bins: List[AtomicGroup] = []
    for s in order:
        need = s.length * c.m_token
        # Best fit: the bin whose headroom is smallest but sufficient.
        best: AtomicGroup | None = None
        for b in bins:
            if b.headroom >= need and (best is None or b.headroom < best.headroom):
                best = b
        if best is not None:
            best.seqs.append(s)
            best.used += need
            continue
        d_min = max(1, math.ceil(need / e_act))
        if max_degree is not None:
            if d_min > max_degree:
                raise ValueError(
                    f"sequence of {s.length} tokens needs CP degree {d_min} "
                    f"> available ranks {max_degree}")
        bins.append(AtomicGroup(
            seqs=[s], d_min=d_min,
            capacity=min(d_min * e_act, max(cap_clip, need)), used=need))
    return bins


def fill_modality_row(row: np.ndarray, spans, offset: int, length: int,
                      next_id: int) -> int:
    """Write one sequence's bidirectional-span ids into a modality table
    row: tokens of the SAME bidirectional block share a nonnegative id
    (unique within the row as numbered from `next_id`); causal text and
    padding stay -1. Returns the next free id."""
    if spans:
        for sp in spans:
            if sp.attn != "bidirectional":
                continue
            a = offset + sp.start
            b = min(offset + sp.start + sp.length, offset + length)
            if b > a:
                row[a:b] = next_id
                next_id += 1
    return next_id


def fill_loss_row(cls_row: np.ndarray, lm_row: np.ndarray, spans,
                  offset: int, length: int) -> None:
    """Label-token modality classes + NLL loss mask for ONE sequence's
    slice of a batch row.

    Position i predicts token i+1 (the label), so a span covering
    tokens [start, end) owns LABEL positions [start-1, end-1). Classes
    default to "text" (scalar sequences have no structure); spans
    override with their modality. Labels inside a BIDIRECTIONAL span
    are zeroed out of `lm_row`: those tokens attend their own future
    within the block, so next-token NLL on them is leaky (and a vision
    patch / audio window id is not a meaningful LM target anyway) —
    they stay visible to telemetry through `cls_row` + the base mask."""
    if length > 1:
        cls_row[offset:offset + length - 1] = 0        # causal text default
    if not spans:
        return
    for sp in spans:
        a = max(sp.start - 1, 0)
        b = min(sp.end - 1, length - 1)
        if b <= a:
            continue
        cls_row[offset + a:offset + b] = modality_class(sp.modality)
        if sp.attn == ATTN_BIDIRECTIONAL:
            lm_row[offset + a:offset + b] = 0.0


def flatten_group(
    seqs: Seq[np.ndarray],
    bucket: int,
    pad_id: int = 0,
    spans: Optional[Seq] = None,
) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
    """Concatenate an atomic group's sequences into ONE packed buffer.

    The executor's packed varlen path: instead of padding each sequence
    to a per-sequence bucket ([n_seqs, bucket] with up to ~2x waste),
    all tokens live in a single [1, bucket] row padded only at the TAIL.
    The executable shape stops depending on n_seqs entirely.

    `spans` (optional) is a per-sequence list of `ModalitySpan` tuples
    (parallel to `seqs`; entries may be None) describing each
    sequence's modality layout. The `modality_ids` table is emitted
    ONLY when at least one entry is non-None — pure-causal batches
    keep the exact pre-span batch dict, so they never pay for the
    mixed-mask attention path.

    Returns `(batch, cu_seqlens)`:
      batch = {tokens, labels, mask, positions, segment_ids
        [, modality_ids, loss_mask, modality_classes]}, all [1, bucket].
        positions reset at every segment boundary (RoPE sees each
        sequence at its own offsets); segment_ids is the block-diagonal
        attention table (-1 = tail padding); modality_ids marks
        bidirectional modality blocks — tokens of one vision/audio span
        share a nonnegative id unique within the buffer, causal text
        and padding are -1 (the mixed mask lets i attend j>i only
        inside one block); labels are next-token WITHIN each segment —
        the last token of a segment is masked, never predicting across
        a boundary. For span-bearing groups, `loss_mask` is `mask` with
        labels inside bidirectional spans zeroed (those tokens attend
        their own future — training on them is leaky; see
        fill_loss_row) and `modality_classes` is the label token's
        MODALITY_CLASSES index (-1 where no label) for per-modality
        loss reporting.
      cu_seqlens = int32 [n_seqs + 1] cumulative offsets (the standard
        varlen format: segment i spans [cu[i], cu[i+1])). Host-side
        metadata only — it is NOT shipped to the device, so its length
        cannot re-trigger compilation.
    """
    total = int(sum(len(s) for s in seqs))
    if total > bucket:
        raise ValueError(f"packed tokens {total} exceed bucket {bucket}")
    if spans is not None and not any(spans):
        spans = None
    tokens = np.full((1, bucket), pad_id, np.int32)
    labels = np.full((1, bucket), pad_id, np.int32)
    mask = np.zeros((1, bucket), np.float32)
    positions = np.zeros((1, bucket), np.int32)
    segment_ids = np.full((1, bucket), -1, np.int32)
    modality_ids = (np.full((1, bucket), -1, np.int32)
                    if spans is not None else None)
    classes = (np.full((1, bucket), -1, np.int32)
               if spans is not None else None)
    cu = np.zeros(len(seqs) + 1, np.int32)
    off = 0
    next_mod = 0
    for i, s in enumerate(seqs):
        L = len(s)
        tokens[0, off:off + L] = s
        if L > 1:
            labels[0, off:off + L - 1] = s[1:]
            mask[0, off:off + L - 1] = 1.0
        positions[0, off:off + L] = np.arange(L, dtype=np.int32)
        segment_ids[0, off:off + L] = i
        if modality_ids is not None:
            next_mod = fill_modality_row(
                modality_ids[0], spans[i], off, L, next_mod)
        off += L
        cu[i + 1] = off
    batch = {"tokens": tokens, "labels": labels, "mask": mask,
             "positions": positions, "segment_ids": segment_ids}
    if modality_ids is not None:
        loss_mask = mask.copy()
        for i in range(len(seqs)):
            fill_loss_row(classes[0], loss_mask[0], spans[i],
                          int(cu[i]), int(cu[i + 1] - cu[i]))
        batch["modality_ids"] = modality_ids
        batch["loss_mask"] = loss_mask
        batch["modality_classes"] = classes
    return batch, cu


def packing_efficiency(cu_seqlens: np.ndarray, bucket: int) -> float:
    """real tokens / padded bucket — the waste metric DHP's a1(1+eta)|s|^2
    term pays for (1.0 = no padding)."""
    return float(cu_seqlens[-1]) / float(bucket) if bucket else 0.0


def validate_packing(groups: Seq[AtomicGroup], cost_model: CostModel,
                     budget: float) -> None:
    """Asserts Eq. (3): M(C_p) <= E * d_p at d_p = d_min for every bin."""
    for g in groups:
        mem = cost_model.memory(g.seqs)
        assert mem <= budget * g.d_min + 1e-6, (
            f"packing violated memory: {mem} > {budget} * {g.d_min}")
