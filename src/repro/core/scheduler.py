"""DHP Scheduler — overall workflow of Fig. 3.

Global batch --(micro-batch planner)--> micro-batches
 --(Stage 1: memory-aware BFD packing)--> atomic groups
 --(Stage 2: 2D-DP allocator)--> CP degrees + assignment
 --> ExecutionPlan consumed by the executor.

The scheduler is pure host-side Python (numpy-free hot path) so it can
run asynchronously with device computation — `prepare()` schedules the
*next* batch on a background thread while the accelerator crunches the
current one, reproducing the paper's producer-consumer decoupling
(§5 Implementation (2)).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence as Seq

from .allocator import Allocation, allocate
from .cost_model import CostModel, SeqInfo
from .packing import AtomicGroup, pack_sequences


@dataclasses.dataclass
class GroupPlan:
    """One CP group within a micro-batch: which sequences, what degree."""

    seq_ids: List[int]
    degree: int
    est_time: float
    tokens: int


@dataclasses.dataclass
class MicroBatchPlan:
    groups: List[GroupPlan]
    makespan: float            # max est_time (the DP objective, Eq. 2)
    ranks_used: int


@dataclasses.dataclass
class ExecutionPlan:
    micro_batches: List[MicroBatchPlan]
    total_time_est: float
    schedule_ms: float         # end-to-end scheduling latency (Table 1/2)
    solver_ms: float           # 2D-DP time alone (Table 1/2)
    strategy_name: str = ""    # which registered strategy produced this
    stage_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-stage scheduling latency, e.g. {"microbatch": .., "pack": ..,
    # "allocate": ..} — lets benchmarks attribute plan cost per stage
    # and per strategy from one code path.

    @property
    def n_groups(self) -> int:
        return sum(len(mb.groups) for mb in self.micro_batches)

    @property
    def degree_histogram(self) -> dict:
        """{degree: count} across all micro-batches — Table 4 case study."""
        h: dict = {}
        for mb in self.micro_batches:
            for g in mb.groups:
                h[g.degree] = h.get(g.degree, 0) + 1
        return dict(sorted(h.items(), reverse=True))


class MicroBatchPlanner:
    """Chunks a global batch into micro-batches under a token budget.

    Sequences are sorted descending and bucketed so each micro-batch's
    total activation footprint fits the cluster (N ranks x E budget) —
    the necessary feasibility condition for Stage 1.
    """

    def __init__(self, cost_model: CostModel, n_ranks: int, budget: float):
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = budget

    def plan(self, seqs: Seq[SeqInfo]) -> List[List[SeqInfo]]:
        c = self.cm.coeffs
        cap = (self.budget - c.m_ms) * self.n_ranks
        order = sorted(seqs, key=lambda s: s.length, reverse=True)
        micro: List[List[SeqInfo]] = []
        cur: List[SeqInfo] = []
        used = 0.0
        for s in order:
            need = s.length * c.m_token
            if cur and used + need > cap:
                micro.append(cur)
                cur, used = [], 0.0
            cur.append(s)
            used += need
        if cur:
            micro.append(cur)
        return micro


def _feasible_waves(groups, n_ranks):
    """Partition atomic groups into waves with sum(d_min) <= n_ranks.

    Greedy first-fit-decreasing on d_min; each wave is scheduled by one
    2D-DP call and waves execute back-to-back.
    """
    waves, loads = [], []
    for g in sorted(groups, key=lambda g: g.d_min, reverse=True):
        for i, load in enumerate(loads):
            if load + g.d_min <= n_ranks:
                waves[i].append(g)
                loads[i] += g.d_min
                break
        else:
            waves.append([g])
            loads.append(g.d_min)
    return waves


class DHPScheduler:
    """The paper's Scheduler class (§5): plans one global batch."""

    def __init__(
        self,
        cost_model: CostModel,
        n_ranks: int,
        mem_budget: float,
        *,
        use_all_ranks: bool = True,
        balance_packing: bool = True,
        serial_fallback: bool = True,
        allocator: Optional[Callable] = None,
    ):
        """`balance_packing` and `serial_fallback` are BEYOND-PAPER
        refinements (see EXPERIMENTS.md §Perf); disable both for the
        paper-faithful scheduler.

        `allocator` swaps the Stage-2 solver (default: the 2D-DP
        `allocate`; pass `allocate_bruteforce` for the exact oracle —
        only tractable on small waves)."""
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = mem_budget
        self.use_all_ranks = use_all_ranks
        self.balance_packing = balance_packing
        self.serial_fallback = serial_fallback
        self.allocator = allocator if allocator is not None else allocate
        self.planner = MicroBatchPlanner(cost_model, n_ranks, mem_budget)
        import inspect
        self._alloc_kwargs = (
            {"use_all_ranks": use_all_ranks}
            if "use_all_ranks" in inspect.signature(
                self.allocator).parameters else {})
        # legacy async surface (repro.api.Strategy carries its own
        # producer-consumer thread); created lazily on first prepare()
        # so the common schedule()-only path allocates no thread pool.
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._pending: Optional[concurrent.futures.Future] = None

    # -- synchronous API ----------------------------------------------------
    def schedule(self, seqs: Seq[SeqInfo]) -> ExecutionPlan:
        t0 = time.perf_counter()
        micro_plans: List[MicroBatchPlan] = []
        solver_ms = 0.0
        micro_batches = self.planner.plan(seqs)
        t_micro = time.perf_counter()
        stage_ms = {"microbatch": (t_micro - t0) * 1e3,
                    "pack": 0.0, "allocate": 0.0}
        for mb in micro_batches:
            t_pack = time.perf_counter()
            all_groups = pack_sequences(
                mb, self.cm, self.budget, max_degree=self.n_ranks,
                balance_over=self.n_ranks if self.balance_packing
                else None)
            stage_ms["pack"] += (time.perf_counter() - t_pack) * 1e3
            # BFD fragmentation can leave sum(d_min) > N for one wave;
            # partition atomic groups into sequential feasible waves.
            for groups in _feasible_waves(all_groups, self.n_ranks):
                t_alloc = time.perf_counter()
                alloc: Allocation = self.allocator(
                    groups, self.n_ranks, self.cm.group_time,
                    **self._alloc_kwargs)
                stage_ms["allocate"] += (
                    time.perf_counter() - t_alloc) * 1e3
                solver_ms += alloc.solver_ms
                # BEYOND-PAPER: serial fallback. The DP runs the wave's
                # groups CONCURRENTLY on disjoint rank sets (Eq. 2-6);
                # when per-group imbalance exceeds the ring-comm cost of
                # width-N groups, running them back-to-back at full
                # degree is faster (dominates at small N). Take the min.
                serial = [self.cm.group_time(g.seqs, self.n_ranks)
                          for g in groups]
                if self.serial_fallback and sum(serial) < alloc.makespan:
                    for g, t in zip(groups, serial):
                        micro_plans.append(MicroBatchPlan(
                            groups=[GroupPlan(
                                seq_ids=[s.seq_id for s in g.seqs],
                                degree=self.n_ranks, est_time=t,
                                tokens=g.total_tokens)],
                            makespan=t, ranks_used=self.n_ranks))
                    continue
                gplans = [
                    GroupPlan(
                        seq_ids=[s.seq_id for s in g.seqs],
                        degree=d,
                        est_time=self.cm.group_time(g.seqs, d),
                        tokens=g.total_tokens,
                    )
                    for g, d in zip(groups, alloc.degrees)
                ]
                micro_plans.append(MicroBatchPlan(
                    groups=gplans, makespan=alloc.makespan,
                    ranks_used=alloc.ranks_used))
        schedule_ms = (time.perf_counter() - t0) * 1e3
        return ExecutionPlan(
            micro_batches=micro_plans,
            total_time_est=sum(m.makespan for m in micro_plans),
            schedule_ms=schedule_ms,
            solver_ms=solver_ms,
            strategy_name="dhp",
            stage_ms=stage_ms,
        )

    # -- asynchronous producer-consumer API ----------------------------------
    def prepare(self, next_seqs: Seq[SeqInfo]) -> None:
        """Kick off scheduling of the NEXT batch on the host thread."""
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1)
        self._pending = self._pool.submit(self.schedule, list(next_seqs))

    def collect(self) -> ExecutionPlan:
        """Block until the prepared plan is ready (usually already done)."""
        assert self._pending is not None, "prepare() was never called"
        plan = self._pending.result()
        self._pending = None
        return plan

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None


def static_plan(
    seqs: Seq[SeqInfo],
    cost_model: CostModel,
    n_ranks: int,
    mem_budget: float,
    *,
    degree: Optional[int] = None,
    power_of_two: bool = False,
) -> ExecutionPlan:
    """Static-parallelism baseline (Megatron-LM / DeepSpeed style).

    One fixed CP degree for every group, sized for the LONGEST sequence
    in the batch (how a practitioner must configure a static system).
    `power_of_two=True` additionally rounds the degree up to a power of
    two (DeepSpeed-Ulysses head-divisibility restriction, §4.1).

    The cluster forms floor(N/d) concurrent DP x CP groups; sequences are
    dealt round-robin in arrival order (static systems are not
    load-aware — this IS the pathology of Fig. 2). Each group chunks its
    share into memory-feasible micro-batches processed sequentially; the
    iteration time is the max over groups (synchronous gradient update).

    The plan emits one MicroBatchPlan per *wave* (chunk j of every
    lane), so each wave satisfies Eq. 6 (sum of degrees <= N) and the
    executor's host sync between micro-batches gives the sequential
    chunks their sequential semantics — per-rank memory stays within
    budget. `total_time_est` is still max-over-lanes of the lane total
    (DP lanes run independently; they do not barrier per chunk).
    """
    t0 = time.perf_counter()
    cm = cost_model
    if degree is None:
        degree = max(cm.min_degree([s], mem_budget) for s in seqs)
    if power_of_two:
        d = 1
        while d < degree:
            d *= 2
        degree = d
    degree = min(degree, n_ranks)
    cap = (mem_budget - cm.coeffs.m_ms) * degree
    n_groups = max(1, n_ranks // degree)

    shares: List[List[SeqInfo]] = [[] for _ in range(n_groups)]
    for i, s in enumerate(seqs):
        shares[i % n_groups].append(s)

    def group_total(share: List[SeqInfo]) -> tuple[float, List[GroupPlan]]:
        """Sequentially process micro-batches that fit d*E_act memory."""
        total, plans = 0.0, []
        cur: List[SeqInfo] = []
        used = 0.0
        for s in share:
            need = s.length * cm.coeffs.m_token
            if cur and used + need > cap:
                t = cm.group_time(cur, degree)
                plans.append(GroupPlan([x.seq_id for x in cur], degree, t,
                                       sum(x.length for x in cur)))
                total += t
                cur, used = [], 0.0
            cur.append(s)
            used += need
        if cur:
            t = cm.group_time(cur, degree)
            plans.append(GroupPlan([x.seq_id for x in cur], degree, t,
                                   sum(x.length for x in cur)))
            total += t
        return total, plans

    lane_plans: List[List[GroupPlan]] = []
    lane_times = []
    for share in shares:
        t, plans = group_total(share)
        lane_times.append(t)
        lane_plans.append(plans)
    total = max(lane_times)
    micro = []
    for wave in range(max(len(p) for p in lane_plans)):
        groups = [p[wave] for p in lane_plans if wave < len(p)]
        micro.append(MicroBatchPlan(
            groups=groups,
            makespan=max(g.est_time for g in groups),
            ranks_used=len(groups) * degree))
    ms = (time.perf_counter() - t0) * 1e3
    return ExecutionPlan(micro_batches=micro, total_time_est=total,
                         schedule_ms=ms, solver_ms=0.0,
                         strategy_name="static", stage_ms={"plan": ms})
