"""DHP Scheduler — overall workflow of Fig. 3.

Global batch --(micro-batch planner)--> micro-batches
 --(Stage 1: memory-aware BFD packing)--> atomic groups
 --(Stage 2: 2D-DP allocator)--> CP degrees + assignment
 --> ExecutionPlan consumed by the executor.

The scheduler is pure host-side Python (numpy-free hot path) so it can
run asynchronously with device computation — `prepare()` schedules the
*next* batch on a background thread while the accelerator crunches the
current one, reproducing the paper's producer-consumer decoupling
(§5 Implementation (2)).
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import json
import threading
import time
from collections import OrderedDict
from typing import (Any, Callable, Dict, List, Optional, Sequence as Seq,
                    Tuple)

from ..obs.trace import get_tracer
from .allocator import Allocation, IncrementalAllocator, allocate
from .cost_model import CostModel, ModalitySpan, SeqInfo
from .packing import AtomicGroup, pack_sequences

#: Plan IR version stamped into every serialized plan. v1 was the
#: in-memory-only dataclass of PR 1; v2 adds to_json/from_json,
#: structural hashing, GroupDelta and validation; v3 adds the optional
#: per-sequence modality-span table (`seq_spans`). v3 still READS v2
#: files, and a span-free v3 plan hashes identically to its v2 form,
#: so old traces keep verifying.
PLAN_IR_VERSION = 3


class PlanValidationError(ValueError):
    """An ExecutionPlan violated a scheduling invariant (Eq. 3/6 or
    seq-id coverage)."""


@dataclasses.dataclass
class GroupPlan:
    """One CP group within a micro-batch: which sequences, what degree."""

    seq_ids: List[int]
    degree: int
    est_time: float
    tokens: int

    def to_json(self) -> dict:
        return {"seq_ids": list(self.seq_ids), "degree": self.degree,
                "est_time": self.est_time, "tokens": self.tokens}

    @classmethod
    def from_json(cls, obj: dict) -> "GroupPlan":
        return cls(seq_ids=[int(i) for i in obj["seq_ids"]],
                   degree=int(obj["degree"]),
                   est_time=float(obj["est_time"]),
                   tokens=int(obj["tokens"]))


@dataclasses.dataclass
class MicroBatchPlan:
    groups: List[GroupPlan]
    makespan: float            # max est_time (the DP objective, Eq. 2)
    ranks_used: int

    def to_json(self) -> dict:
        return {"groups": [g.to_json() for g in self.groups],
                "makespan": self.makespan, "ranks_used": self.ranks_used}

    @classmethod
    def from_json(cls, obj: dict) -> "MicroBatchPlan":
        return cls(groups=[GroupPlan.from_json(g) for g in obj["groups"]],
                   makespan=float(obj["makespan"]),
                   ranks_used=int(obj["ranks_used"]))


@dataclasses.dataclass
class GroupDelta:
    """What changed in the communication-group layout vs the PREVIOUS
    plan.

    Groups are named by their (start, degree) rank slot — the same key
    the GroupPool caches meshes/executables under — so a delta tells the
    pool exactly which artifacts to reuse and which to (re)create:

      reused   — slot occupied by both plans (zero reconfiguration cost);
      resized  — start rank kept, CP degree changed (new ring size);
      created  — slot that did not exist in the previous plan;
      released — previous slot whose start rank the new plan leaves
                 entirely (kept pooled, not destroyed).
    """

    created: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)
    reused: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)
    resized: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)
    released: List[Tuple[int, int]] = dataclasses.field(
        default_factory=list)

    @property
    def n_reconfigured(self) -> int:
        """Slots needing (re)creation — the paper's per-batch group
        setup cost the pool amortises."""
        return len(self.created) + len(self.resized)

    def summary(self) -> str:
        return (f"groups: {len(self.reused)} reused, "
                f"{len(self.created)} created, "
                f"{len(self.resized)} resized, "
                f"{len(self.released)} released")

    def to_json(self) -> dict:
        return {k: [list(s) for s in getattr(self, k)]
                for k in ("created", "reused", "resized", "released")}

    @classmethod
    def from_json(cls, obj: dict) -> "GroupDelta":
        return cls(**{k: [tuple(int(x) for x in s) for s in obj[k]]
                      for k in ("created", "reused", "resized",
                                "released")})


@dataclasses.dataclass
class ExecutionPlan:
    micro_batches: List[MicroBatchPlan]
    total_time_est: float
    schedule_ms: float         # end-to-end scheduling latency (Table 1/2)
    solver_ms: float           # 2D-DP time alone (Table 1/2)
    strategy_name: str = ""    # which registered strategy produced this
    stage_ms: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-stage scheduling latency, e.g. {"microbatch": .., "pack": ..,
    # "allocate": ..} — lets benchmarks attribute plan cost per stage
    # and per strategy from one code path.
    version: int = PLAN_IR_VERSION
    from_cache: bool = False   # True when a PlanCache hit produced this
    replan_mode: str = "full"
    # which planning path produced this plan: "full" (cold solve),
    # "incremental" (warm-started DP suffix re-solve) or "cache"
    # (PlanCache structural hit). Telemetry only — excluded from the
    # structural hash, so plans from different paths still compare
    # equal when their structure is equal.
    delta: Optional[GroupDelta] = None
    # group reconfiguration vs the previously executed plan; filled by
    # diff_plans (the Engine does it automatically before execution).
    seq_spans: Optional[Dict[int, Tuple[ModalitySpan, ...]]] = None
    # per-sequence modality layout (seq_id -> spans) for span-bearing
    # batches; Strategy.plan attaches it from the input sequences so a
    # saved trace records the structure its costs were derived from.

    @property
    def n_groups(self) -> int:
        return sum(len(mb.groups) for mb in self.micro_batches)

    @property
    def degree_histogram(self) -> dict:
        """{degree: count} across all micro-batches — Table 4 case study."""
        h: dict = {}
        for mb in self.micro_batches:
            for g in mb.groups:
                h[g.degree] = h.get(g.degree, 0) + 1
        return dict(sorted(h.items(), reverse=True))

    # -- rank-slot geometry ---------------------------------------------
    def group_slots(self, n_ranks: int) -> List[Tuple[int, int, int, int]]:
        """(mb_index, group_index, start_rank, degree) per group, using
        the SAME cursor rule as the executor (including the defensive
        wrap for oversubscribed micro-batches) — the single source of
        truth for which rank slice a group runs on, shared by the
        executor, diff_plans and replay equality checks."""
        slots = []
        for mi, mb in enumerate(self.micro_batches):
            start = 0
            for gi, g in enumerate(mb.groups):
                if start + g.degree > n_ranks:
                    start = 0
                slots.append((mi, gi, start, g.degree))
                start += g.degree
        return slots

    # -- structural identity --------------------------------------------
    def _spans_tree(self) -> Optional[list]:
        if not self.seq_spans:
            return None
        return sorted(
            [int(sid), [sp.to_json() for sp in spans]]
            for sid, spans in self.seq_spans.items())

    def structural_hash(self) -> str:
        """Stable digest of the plan STRUCTURE (micro-batch tree of
        (seq_ids, degree), plus the modality-span table when present —
        two plans over batches of equal lengths but different span
        layouts have different costs, so they must hash apart)."""
        tree = [[[list(g.seq_ids), g.degree] for g in mb.groups]
                for mb in self.micro_batches]
        spans = self._spans_tree()
        # structure only — no version salt, and span-free plans keep the
        # exact v2 blob, so traces saved by older IR versions still
        # hash-verify
        blob = json.dumps(tree if spans is None else [tree, spans],
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    # -- invariants ------------------------------------------------------
    def validate(self, seqs: Optional[Seq[SeqInfo]] = None, *,
                 n_ranks: Optional[int] = None,
                 cost_model: Optional[CostModel] = None,
                 mem_budget: Optional[float] = None) -> "ExecutionPlan":
        """Check scheduling invariants; raises PlanValidationError.

        Checks are keyed to what context is supplied:
          * always        — degrees >= 1, non-empty groups;
          * `n_ranks`     — wave feasibility, Eq. 6: per micro-batch
                            sum(degrees) <= N and each degree <= N;
          * `seqs`        — coverage: every seq_id scheduled exactly once;
          * `seqs` + `cost_model` + `mem_budget`
                          — memory, Eq. 3: M(C_p) <= E * d_p per group.
        Returns self so call sites can chain."""
        by_id = {s.seq_id: s for s in seqs} if seqs is not None else None
        seen: Dict[int, int] = {}
        for mi, mb in enumerate(self.micro_batches):
            wave_degrees = 0
            for g in mb.groups:
                if g.degree < 1:
                    raise PlanValidationError(
                        f"mb{mi}: group degree {g.degree} < 1")
                if not g.seq_ids:
                    raise PlanValidationError(f"mb{mi}: empty group")
                wave_degrees += g.degree
                for i in g.seq_ids:
                    seen[i] = seen.get(i, 0) + 1
                if (by_id is not None and cost_model is not None
                        and mem_budget is not None):
                    try:
                        gseqs = [by_id[i] for i in g.seq_ids]
                    except KeyError as e:
                        raise PlanValidationError(
                            f"mb{mi}: unknown seq_id {e.args[0]}") from e
                    mem = cost_model.memory(gseqs)
                    if mem > mem_budget * g.degree + 1e-6:
                        raise PlanValidationError(
                            f"mb{mi}: memory {mem:.3g} > budget "
                            f"{mem_budget:.3g} x degree {g.degree} "
                            f"(Eq. 3)")
            if n_ranks is not None and wave_degrees > n_ranks:
                raise PlanValidationError(
                    f"mb{mi}: sum of degrees {wave_degrees} > ranks "
                    f"{n_ranks} (Eq. 6 wave feasibility)")
        if by_id is not None:
            dup = {i: c for i, c in seen.items() if c > 1}
            missing = set(by_id) - set(seen)
            extra = set(seen) - set(by_id)
            if dup or missing or extra:
                raise PlanValidationError(
                    f"seq-id coverage broken: duplicated={sorted(dup)} "
                    f"missing={sorted(missing)} extra={sorted(extra)}")
        return self

    # -- serialization ---------------------------------------------------
    def to_json(self) -> dict:
        """JSON-serializable dict, version-stamped and hash-stamped."""
        return {
            "version": PLAN_IR_VERSION,
            "strategy_name": self.strategy_name,
            "structural_hash": self.structural_hash(),
            "total_time_est": self.total_time_est,
            "schedule_ms": self.schedule_ms,
            "solver_ms": self.solver_ms,
            "stage_ms": dict(self.stage_ms),
            "from_cache": self.from_cache,
            "replan_mode": self.replan_mode,
            "micro_batches": [mb.to_json() for mb in self.micro_batches],
            "delta": self.delta.to_json() if self.delta else None,
            "seq_spans": (None if not self.seq_spans else {
                str(sid): [sp.to_json() for sp in spans]
                for sid, spans in self.seq_spans.items()}),
        }

    @classmethod
    def from_json(cls, obj: dict) -> "ExecutionPlan":
        v = int(obj.get("version", 1))
        if v > PLAN_IR_VERSION:
            raise ValueError(
                f"plan IR version {v} is newer than supported "
                f"{PLAN_IR_VERSION}")
        plan = cls(
            micro_batches=[MicroBatchPlan.from_json(mb)
                           for mb in obj["micro_batches"]],
            total_time_est=float(obj["total_time_est"]),
            schedule_ms=float(obj.get("schedule_ms", 0.0)),
            solver_ms=float(obj.get("solver_ms", 0.0)),
            strategy_name=obj.get("strategy_name", ""),
            stage_ms=dict(obj.get("stage_ms", {})),
            version=PLAN_IR_VERSION,
            from_cache=bool(obj.get("from_cache", False)),
            replan_mode=str(obj.get("replan_mode", "full")),
            delta=(GroupDelta.from_json(obj["delta"])
                   if obj.get("delta") else None),
            seq_spans=(None if not obj.get("seq_spans") else {
                int(sid): tuple(ModalitySpan.from_json(sp)
                                for sp in spans)
                for sid, spans in obj["seq_spans"].items()}),
        )
        want = obj.get("structural_hash")
        if want is not None and plan.structural_hash() != want:
            raise ValueError(
                f"plan structural hash mismatch: stored {want}, "
                f"reconstructed {plan.structural_hash()} — corrupt or "
                f"hand-edited plan file")
        return plan


def diff_plans(prev: Optional[ExecutionPlan], cur: ExecutionPlan,
               n_ranks: int) -> GroupDelta:
    """Group-reconfiguration delta between two consecutive plans.

    Slots are the deduplicated (start, degree) rank slices each plan
    occupies (via `group_slots`); `prev=None` means cold start — every
    slot is `created`."""
    cur_slots = sorted({(s, d) for _, _, s, d
                        in cur.group_slots(n_ranks)})
    if prev is None:
        return GroupDelta(created=list(cur_slots))
    prev_slots = {(s, d) for _, _, s, d in prev.group_slots(n_ranks)}
    prev_starts = {s for s, _ in prev_slots}
    delta = GroupDelta()
    for slot in cur_slots:
        if slot in prev_slots:
            delta.reused.append(slot)
        elif slot[0] in prev_starts:
            delta.resized.append(slot)
        else:
            delta.created.append(slot)
    cur_starts = {s for s, _ in cur_slots}
    delta.released = sorted(slot for slot in prev_slots
                            if slot[0] not in cur_starts)
    return delta


# -- persistence -------------------------------------------------------------
def plans_to_json(plans: Seq[ExecutionPlan]) -> dict:
    """A run's plan trace as one JSON document (the --save-plans file)."""
    return {"version": PLAN_IR_VERSION,
            "plans": [p.to_json() for p in plans]}


def plans_from_json(obj: dict) -> List[ExecutionPlan]:
    v = int(obj.get("version", 1))
    if v > PLAN_IR_VERSION:
        raise ValueError(f"plan file version {v} > {PLAN_IR_VERSION}")
    return [ExecutionPlan.from_json(p) for p in obj["plans"]]


def save_plans(path: str, plans: Seq[ExecutionPlan]) -> None:
    with open(path, "w") as f:
        json.dump(plans_to_json(plans), f, indent=1)


def load_plans(path: str) -> List[ExecutionPlan]:
    with open(path) as f:
        return plans_from_json(json.load(f))


# -- plan cache --------------------------------------------------------------
def _default_cache_bucket(n: int) -> int:
    b = 64
    while b < n:
        b *= 2
    return b


class PlanCache:
    """LRU cache of ExecutionPlans keyed on the batch's bucketed length
    histogram.

    Recurring batch *shapes* — the common case under bucketed data
    sampling — skip Stage 1 + the 2D-DP solver entirely: the cached
    plan's structure is reused with seq_ids remapped onto the new batch
    (both batches sorted by descending length, matched positionally) and
    per-group time estimates re-evaluated for the actual lengths. A
    remap whose memory invariant (Eq. 3) fails — same bucket, different
    d_min — is treated as a miss, so hits are always feasible plans.
    """

    def __init__(self, capacity: int = 64,
                 bucket_fn: Optional[Callable[[int], int]] = None,
                 salt: Any = None):
        """`salt` namespaces the key space so one cache can be shared
        across planning phases (e.g. training batches vs serving
        chunked-prefill batches) without a same-shape batch from one
        phase serving a plan tuned for the other."""
        self.capacity = capacity
        self.bucket_fn = bucket_fn or _default_cache_bucket
        self.salt = salt
        self._entries: "OrderedDict[Any, Tuple[ExecutionPlan, List[SeqInfo]]]" \
            = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        # nearest() warm-reference accounting (separate from hit/miss:
        # a reference is never served as a plan)
        self.nearest_exact = 0
        self.nearest_fallback = 0
        self.nearest_none = 0

    # ------------------------------------------------------------------
    def _span_sig(self, s: SeqInfo) -> Any:
        """Coarse span-layout signature: (bidirectional span count,
        bucketed bidirectional token total, bucketed largest block).
        Two sequences of equal length whose span layouts differ (and
        hence whose DERIVED eta/cost differ) land in different cache
        buckets; scalar SeqInfos keep signature None, so pre-span
        callers see the exact old key space. Deliberately O(1)-sized —
        a long video is hundreds of frame spans, and this tuple is
        hashed/sorted on every plan() call."""
        spans = getattr(s, "spans", None)
        if not spans:
            return None
        n = total = biggest = 0
        for sp in spans:
            if sp.attn == "bidirectional":
                n += 1
                total += sp.length
                biggest = max(biggest, sp.length)
        if n == 0:
            return (0, 0, 0)
        return (n, self.bucket_fn(total), self.bucket_fn(biggest))

    def key(self, seqs: Seq[SeqInfo]) -> Any:
        """Structural key: histogram over (length bucket, coarse eta,
        span signature), namespaced by `salt`."""
        h: Dict[Any, int] = {}
        for s in seqs:
            k = (self.bucket_fn(s.length), round(s.eta, 2),
                 self._span_sig(s))
            h[k] = h.get(k, 0) + 1
        return (self.salt, tuple(sorted(h.items(), key=repr)))

    @staticmethod
    def _order(seqs: Seq[SeqInfo]) -> List[SeqInfo]:
        return sorted(seqs, key=lambda s: (-s.length, s.seq_id))

    # ------------------------------------------------------------------
    def lookup(self, seqs: Seq[SeqInfo], *,
               cost_model: Optional[CostModel] = None,
               n_ranks: Optional[int] = None,
               mem_budget: Optional[float] = None
               ) -> Optional[ExecutionPlan]:
        """Return a plan for `seqs` remapped from a cached same-shape
        batch, or None (miss)."""
        k = self.key(seqs)
        with self._lock:
            entry = self._entries.get(k)
            if entry is not None:
                self._entries.move_to_end(k)
        if entry is None:
            self.misses += 1
            return None
        cached_plan, cached_seqs = entry
        remap = {old.seq_id: new.seq_id
                 for old, new in zip(self._order(cached_seqs),
                                     self._order(seqs))}
        by_id = {s.seq_id: s for s in seqs}
        micro = []
        for mb in cached_plan.micro_batches:
            groups = []
            for g in mb.groups:
                ids = [remap[i] for i in g.seq_ids]
                gseqs = [by_id[i] for i in ids]
                est = (cost_model.group_time(gseqs, g.degree)
                       if cost_model is not None else g.est_time)
                groups.append(GroupPlan(
                    seq_ids=ids, degree=g.degree, est_time=est,
                    tokens=sum(s.length for s in gseqs)))
            micro.append(MicroBatchPlan(
                groups=groups,
                makespan=max(g.est_time for g in groups),
                ranks_used=mb.ranks_used))
        plan = ExecutionPlan(
            micro_batches=micro,
            total_time_est=sum(m.makespan for m in micro),
            schedule_ms=0.0, solver_ms=0.0,
            strategy_name=cached_plan.strategy_name,
            stage_ms={}, from_cache=True, replan_mode="cache")
        try:
            plan.validate(seqs, n_ranks=n_ranks, cost_model=cost_model,
                          mem_budget=mem_budget)
        except PlanValidationError:
            # same histogram bucket but a different d_min — do not serve
            # an infeasible plan; replan (and let store() refresh it).
            self.misses += 1
            return None
        self.hits += 1
        return plan

    def nearest(self, seqs: Seq[SeqInfo]) -> Optional[ExecutionPlan]:
        """The stored plan whose batch histogram is CLOSEST to `seqs`:
        the exact-key entry when one exists, else the entry with the
        largest multiset overlap of (length-bucket, eta, span-sig)
        items. Unlike `lookup` this neither remaps seq_ids nor
        validates — the result is a warm REFERENCE for incremental
        replanning (which groups/degrees a near-identical batch used),
        not an executable plan. Accounted separately from hit/miss
        (`nearest_exact` / `nearest_fallback` / `nearest_none` in
        `stats`): a reference is never served as a plan, so it must not
        distort the cache's hit rate."""
        k = self.key(seqs)
        with self._lock:
            entry = self._entries.get(k)
            if entry is not None:
                self.nearest_exact += 1
                return entry[0]
            if not self._entries:
                self.nearest_none += 1
                return None
            want = dict(k[1])
            best, score = None, -1
            for (_, items), (plan, _) in self._entries.items():
                ov = sum(min(c, want.get(kk, 0)) for kk, c in items)
                if ov > score:
                    best, score = plan, ov
            self.nearest_fallback += 1
            return best

    def store(self, seqs: Seq[SeqInfo], plan: ExecutionPlan) -> None:
        # Deep-copy through the IR so later telemetry mutations on the
        # live plan (delta, schedule_ms) never leak into the cache.
        snapshot = ExecutionPlan.from_json(plan.to_json())
        snapshot.from_cache = False
        with self._lock:
            self._entries[self.key(seqs)] = (snapshot, list(seqs))
            self._entries.move_to_end(self.key(seqs))
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "size": len(self._entries),
                "nearest_exact": self.nearest_exact,
                "nearest_fallback": self.nearest_fallback,
                "nearest_none": self.nearest_none}


class MicroBatchPlanner:
    """Chunks a global batch into micro-batches under a token budget.

    Sequences are sorted descending and bucketed so each micro-batch's
    total activation footprint fits the cluster (N ranks x E budget) —
    the necessary feasibility condition for Stage 1.
    """

    def __init__(self, cost_model: CostModel, n_ranks: int, budget: float):
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = budget

    def plan(self, seqs: Seq[SeqInfo]) -> List[List[SeqInfo]]:
        c = self.cm.coeffs
        cap = (self.budget - c.m_ms) * self.n_ranks
        order = sorted(seqs, key=lambda s: s.length, reverse=True)
        micro: List[List[SeqInfo]] = []
        cur: List[SeqInfo] = []
        used = 0.0
        for s in order:
            need = s.length * c.m_token
            if cur and used + need > cap:
                micro.append(cur)
                cur, used = [], 0.0
            cur.append(s)
            used += need
        if cur:
            micro.append(cur)
        return micro


def _feasible_waves(groups, n_ranks):
    """Partition atomic groups into waves with sum(d_min) <= n_ranks.

    Greedy first-fit-decreasing on d_min; each wave is scheduled by one
    2D-DP call and waves execute back-to-back.
    """
    waves, loads = [], []
    for g in sorted(groups, key=lambda g: g.d_min, reverse=True):
        for i, load in enumerate(loads):
            if load + g.d_min <= n_ranks:
                waves[i].append(g)
                loads[i] += g.d_min
                break
        else:
            waves.append([g])
            loads.append(g.d_min)
    return waves


class DHPScheduler:
    """The paper's Scheduler class (§5): plans one global batch."""

    def __init__(
        self,
        cost_model: CostModel,
        n_ranks: int,
        mem_budget: float,
        *,
        use_all_ranks: bool = True,
        balance_packing: bool = True,
        serial_fallback: bool = True,
        allocator: Optional[Callable] = None,
        incremental: bool = True,
    ):
        """`balance_packing` and `serial_fallback` are BEYOND-PAPER
        refinements (see EXPERIMENTS.md §Perf); disable both for the
        paper-faithful scheduler.

        `allocator` swaps the Stage-2 solver (default: the 2D-DP
        `allocate`; pass `allocate_bruteforce` for the exact oracle —
        only tractable on small waves).

        `incremental` (default on, only with the default solver) keeps
        one `IncrementalAllocator` per wave ordinal so consecutive
        batches warm-start each other's DP: only suffix rows whose
        atomic groups changed are re-solved. Plans are bit-equal to
        the cold solve; `ExecutionPlan.replan_mode` reports which path
        ran."""
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = mem_budget
        self.use_all_ranks = use_all_ranks
        self.balance_packing = balance_packing
        self.serial_fallback = serial_fallback
        self.incremental = incremental and allocator is None
        self._wave_solvers: Dict[int, IncrementalAllocator] = {}
        self.allocator = allocator if allocator is not None else allocate
        self.planner = MicroBatchPlanner(cost_model, n_ranks, mem_budget)
        import inspect
        self._alloc_kwargs = (
            {"use_all_ranks": use_all_ranks}
            if "use_all_ranks" in inspect.signature(
                self.allocator).parameters else {})
        # legacy async surface (repro.api.Strategy carries its own
        # producer-consumer thread); created lazily on first prepare()
        # so the common schedule()-only path allocates no thread pool.
        self._pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._pending: Optional[concurrent.futures.Future] = None

    # -- synchronous API ----------------------------------------------------
    def schedule(self, seqs: Seq[SeqInfo]) -> ExecutionPlan:
        tr = get_tracer()
        t0 = time.perf_counter()
        micro_plans: List[MicroBatchPlan] = []
        solver_ms = 0.0
        micro_batches = self.planner.plan(seqs)
        t_micro = time.perf_counter()
        if tr.enabled:
            tr.complete("microbatch", t0, t_micro - t0, "sched",
                        args={"seqs": len(seqs),
                              "micro_batches": len(micro_batches)})
        stage_ms = {"microbatch": (t_micro - t0) * 1e3,
                    "pack": 0.0, "allocate": 0.0,
                    # the allocate split: cost-table build (time_fn
                    # evaluation) vs the DP itself (+ backtrack)
                    "allocate_cost": 0.0, "allocate_dp": 0.0}
        wave_idx = 0
        rows_reused = 0
        for mb in micro_batches:
            t_pack = time.perf_counter()
            all_groups = pack_sequences(
                mb, self.cm, self.budget, max_degree=self.n_ranks,
                balance_over=self.n_ranks if self.balance_packing
                else None)
            t_packed = time.perf_counter()
            stage_ms["pack"] += (t_packed - t_pack) * 1e3
            if tr.enabled:
                tr.complete("pack", t_pack, t_packed - t_pack, "sched",
                            args={"seqs": len(mb),
                                  "groups": len(all_groups)})
            # BFD fragmentation can leave sum(d_min) > N for one wave;
            # partition atomic groups into sequential feasible waves.
            for groups in _feasible_waves(all_groups, self.n_ranks):
                t_alloc = time.perf_counter()
                if self.incremental:
                    solver = self._wave_solvers.setdefault(
                        wave_idx, IncrementalAllocator())
                    alloc: Allocation = solver(
                        groups, self.n_ranks, self.cm.group_time,
                        use_all_ranks=self.use_all_ranks)
                else:
                    alloc = self.allocator(
                        groups, self.n_ranks, self.cm.group_time,
                        **self._alloc_kwargs)
                wave_idx += 1
                rows_reused += alloc.rows_reused
                stage_ms["allocate"] += (
                    time.perf_counter() - t_alloc) * 1e3
                stage_ms["allocate_cost"] += alloc.cost_ms
                stage_ms["allocate_dp"] += alloc.dp_ms
                solver_ms += alloc.solver_ms
                if tr.enabled:
                    # the allocate split, laid out consecutively from
                    # t_alloc using the allocator's own sub-timers
                    tr.complete("allocate_cost", t_alloc,
                                alloc.cost_ms / 1e3, "sched",
                                args={"wave": wave_idx - 1,
                                      "groups": len(groups)})
                    tr.complete("allocate_dp",
                                t_alloc + alloc.cost_ms / 1e3,
                                alloc.dp_ms / 1e3, "sched",
                                args={"wave": wave_idx - 1,
                                      "mode": alloc.mode,
                                      "rows_reused": alloc.rows_reused,
                                      "makespan_s": alloc.makespan})
                # BEYOND-PAPER: serial fallback. The DP runs the wave's
                # groups CONCURRENTLY on disjoint rank sets (Eq. 2-6);
                # when per-group imbalance exceeds the ring-comm cost of
                # width-N groups, running them back-to-back at full
                # degree is faster (dominates at small N). Take the min.
                serial = [self.cm.group_time(g.seqs, self.n_ranks)
                          for g in groups]
                if self.serial_fallback and sum(serial) < alloc.makespan:
                    for g, t in zip(groups, serial):
                        micro_plans.append(MicroBatchPlan(
                            groups=[GroupPlan(
                                seq_ids=[s.seq_id for s in g.seqs],
                                degree=self.n_ranks, est_time=t,
                                tokens=g.total_tokens)],
                            makespan=t, ranks_used=self.n_ranks))
                    continue
                gplans = [
                    GroupPlan(
                        seq_ids=[s.seq_id for s in g.seqs],
                        degree=d,
                        est_time=self.cm.group_time(g.seqs, d),
                        tokens=g.total_tokens,
                    )
                    for g, d in zip(groups, alloc.degrees)
                ]
                micro_plans.append(MicroBatchPlan(
                    groups=gplans, makespan=alloc.makespan,
                    ranks_used=alloc.ranks_used))
        schedule_ms = (time.perf_counter() - t0) * 1e3
        return ExecutionPlan(
            micro_batches=micro_plans,
            total_time_est=sum(m.makespan for m in micro_plans),
            schedule_ms=schedule_ms,
            solver_ms=solver_ms,
            strategy_name="dhp",
            stage_ms=stage_ms,
            replan_mode="incremental" if rows_reused else "full",
        )

    # -- asynchronous producer-consumer API ----------------------------------
    def prepare(self, next_seqs: Seq[SeqInfo]) -> None:
        """Kick off scheduling of the NEXT batch on the host thread."""
        if self._pool is None:
            self._pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1)
        self._pending = self._pool.submit(self.schedule, list(next_seqs))

    def collect(self) -> ExecutionPlan:
        """Block until the prepared plan is ready (usually already done)."""
        assert self._pending is not None, "prepare() was never called"
        plan = self._pending.result()
        self._pending = None
        return plan

    def close(self) -> None:
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None


def static_plan(
    seqs: Seq[SeqInfo],
    cost_model: CostModel,
    n_ranks: int,
    mem_budget: float,
    *,
    degree: Optional[int] = None,
    power_of_two: bool = False,
) -> ExecutionPlan:
    """Static-parallelism baseline (Megatron-LM / DeepSpeed style).

    One fixed CP degree for every group, sized for the LONGEST sequence
    in the batch (how a practitioner must configure a static system).
    `power_of_two=True` additionally rounds the degree up to a power of
    two (DeepSpeed-Ulysses head-divisibility restriction, §4.1).

    The cluster forms floor(N/d) concurrent DP x CP groups; sequences are
    dealt round-robin in arrival order (static systems are not
    load-aware — this IS the pathology of Fig. 2). Each group chunks its
    share into memory-feasible micro-batches processed sequentially; the
    iteration time is the max over groups (synchronous gradient update).

    The plan emits one MicroBatchPlan per *wave* (chunk j of every
    lane), so each wave satisfies Eq. 6 (sum of degrees <= N) and the
    executor's host sync between micro-batches gives the sequential
    chunks their sequential semantics — per-rank memory stays within
    budget. `total_time_est` is still max-over-lanes of the lane total
    (DP lanes run independently; they do not barrier per chunk).

    Stage attribution mirrors the DHP pipeline's keys so benchmarks
    read baseline plan cost through the same code path: degree sizing
    is "allocate", dealing sequences into lanes is "pack", chunking
    lanes into memory-feasible waves is "microbatch".
    """
    t0 = time.perf_counter()
    cm = cost_model
    if degree is None:
        degree = max(cm.min_degree([s], mem_budget) for s in seqs)
    if power_of_two:
        d = 1
        while d < degree:
            d *= 2
        degree = d
    degree = min(degree, n_ranks)
    cap = (mem_budget - cm.coeffs.m_ms) * degree
    n_groups = max(1, n_ranks // degree)
    t_alloc = time.perf_counter()

    shares: List[List[SeqInfo]] = [[] for _ in range(n_groups)]
    for i, s in enumerate(seqs):
        shares[i % n_groups].append(s)
    t_pack = time.perf_counter()

    def group_total(share: List[SeqInfo]) -> tuple[float, List[GroupPlan]]:
        """Sequentially process micro-batches that fit d*E_act memory."""
        total, plans = 0.0, []
        cur: List[SeqInfo] = []
        used = 0.0
        for s in share:
            need = s.length * cm.coeffs.m_token
            if cur and used + need > cap:
                t = cm.group_time(cur, degree)
                plans.append(GroupPlan([x.seq_id for x in cur], degree, t,
                                       sum(x.length for x in cur)))
                total += t
                cur, used = [], 0.0
            cur.append(s)
            used += need
        if cur:
            t = cm.group_time(cur, degree)
            plans.append(GroupPlan([x.seq_id for x in cur], degree, t,
                                   sum(x.length for x in cur)))
            total += t
        return total, plans

    lane_plans: List[List[GroupPlan]] = []
    lane_times = []
    for share in shares:
        t, plans = group_total(share)
        lane_times.append(t)
        lane_plans.append(plans)
    total = max(lane_times)
    micro = []
    for wave in range(max(len(p) for p in lane_plans)):
        groups = [p[wave] for p in lane_plans if wave < len(p)]
        micro.append(MicroBatchPlan(
            groups=groups,
            makespan=max(g.est_time for g in groups),
            ranks_used=len(groups) * degree))
    t_micro = time.perf_counter()
    ms = (t_micro - t0) * 1e3
    return ExecutionPlan(
        micro_batches=micro, total_time_est=total,
        schedule_ms=ms, solver_ms=0.0, strategy_name="static",
        stage_ms={"microbatch": (t_micro - t_pack) * 1e3,
                  "pack": (t_pack - t_alloc) * 1e3,
                  "allocate": (t_alloc - t0) * 1e3})
