"""Stage 2 — Optimal Resource Assignment via 2D Dynamic Programming (Alg. 1).

DP[i][j] = minimum achievable makespan for the first i atomic groups using
a total of exactly j ranks:

    DP[i][j] = min_{d in [d_min_i, j - d']} max(DP[i-1][j-d], T(G_i, d))

with d' = sum_{m<i} d_min_m reserving feasibility for the prefix.
Backtracking from the best final state recovers the CP degrees {d_p}.

Complexity O(K' * N^2) — the paper reports <= 86 ms at K'~512, N=64; our
numpy-free pure-Python implementation is benchmarked in
benchmarks/bench_solver.py (Table 1/2 reproduction).

Deviation from Alg. 1 as printed: the pseudocode backtracks from
DP[K'][N], i.e. forces sum d_p == N. Because T(G,d) is not monotone in d
(ring comm grows with d for short sequences), using *all* ranks can be
strictly worse than leaving some idle; constraint (6) is an inequality.
We therefore backtrack from argmin_j DP[K'][j]. With `use_all_ranks=True`
the exact printed behaviour is available (and is what the paper's
executor wants when idle ranks would otherwise sit in the DP group
anyway — we default to True but surface both).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Callable, List, Sequence as Seq, Tuple

from .packing import AtomicGroup

INF = float("inf")

# T(G_i, d): estimated execution time of atomic group i at CP degree d.
TimeFn = Callable[[Seq, int], float]


@dataclasses.dataclass
class Allocation:
    degrees: List[int]          # d_p per atomic group (same order as input)
    makespan: float             # max_p T(G_p, d_p)
    ranks_used: int
    solver_ms: float


def allocate(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
    *,
    use_all_ranks: bool = True,
) -> Allocation:
    """2D-DP resource allocation (paper Alg. 1)."""
    t0 = time.perf_counter()
    kp = len(groups)
    if kp == 0:
        return Allocation([], 0.0, 0, 0.0)
    d_min = [g.d_min for g in groups]
    pre = list(itertools.accumulate(d_min))          # sum_{i<=k} d_min_i
    if pre[-1] > n_ranks:
        raise ValueError(
            f"infeasible: sum of minimum degrees {pre[-1]} > ranks {n_ranks}")

    # Memoize T(G_i, d) — the DP probes each (i, d) many times.
    cost: List[List[float]] = []
    for i, g in enumerate(groups):
        row = [INF] * (n_ranks + 1)
        for d in range(d_min[i], n_ranks - (pre[-1] - pre[i]) + 1):
            row[d] = time_fn(g.seqs, d)
        cost.append(row)

    dp = [[INF] * (n_ranks + 1) for _ in range(kp + 1)]
    path = [[0] * (n_ranks + 1) for _ in range(kp + 1)]
    dp[0][0] = 0.0
    for k in range(1, kp + 1):
        r_remain = pre[-1] - pre[k - 1]              # ranks still owed to suffix
        lo_j = pre[k - 1]
        hi_j = n_ranks - r_remain
        prev_base = pre[k - 2] if k >= 2 else 0
        dpk, dpk1 = dp[k], dp[k - 1]
        ck, pk = cost[k - 1], path[k]
        for j in range(lo_j, hi_j + 1):
            best, best_d = INF, 0
            for d in range(d_min[k - 1], j - prev_base + 1):
                prev = dpk1[j - d]
                if prev >= best:
                    continue
                c = ck[d] if ck[d] > prev else prev  # max(prev, T(G,d))
                if c < best:
                    best, best_d = c, d
            dpk[j] = best
            pk[j] = best_d

    if use_all_ranks:
        j_best = n_ranks
        if dp[kp][j_best] == INF:   # can happen if hi_j < N for the last row
            j_best = max(j for j in range(n_ranks + 1) if dp[kp][j] < INF)
    else:
        j_best = min(range(n_ranks + 1), key=lambda j: (dp[kp][j], j))
    degrees = [0] * kp
    p, q = kp, j_best
    while p > 0:
        d = path[p][q]
        degrees[p - 1] = d
        p, q = p - 1, q - d
    ms = (time.perf_counter() - t0) * 1e3
    return Allocation(degrees=degrees, makespan=dp[kp][j_best],
                      ranks_used=sum(degrees), solver_ms=ms)


def evaluate_degrees(
    seq_groups: Seq[Seq],
    degrees: Seq[int],
    time_fn: TimeFn,
) -> Allocation:
    """Evaluate a FIXED degree vector — the no-search path.

    Used when the degrees are already known (a cached or replayed plan
    names them), by OracleStrategy.plan_cost to price any plan under
    measured costs, and by tests to certify the DP's reported makespan
    equals the evaluation of its own degree vector.
    """
    t0 = time.perf_counter()
    times = [time_fn(seqs, d) for seqs, d in zip(seq_groups, degrees)]
    ms = (time.perf_counter() - t0) * 1e3
    return Allocation(degrees=list(degrees),
                      makespan=max(times, default=0.0),
                      ranks_used=sum(degrees), solver_ms=ms)


def allocate_bruteforce(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
) -> Allocation:
    """Exhaustive search over degree vectors — oracle for correctness tests.

    Only tractable for tiny instances (used by tests/property checks to
    certify the DP is exactly optimal for the separable makespan
    objective).
    """
    t0 = time.perf_counter()
    kp = len(groups)
    d_min = [g.d_min for g in groups]
    best: Tuple[float, List[int]] = (INF, [])

    def rec(i: int, left: int, cur_max: float, acc: List[int]):
        nonlocal best
        if cur_max >= best[0]:
            return
        if i == kp:
            best = (cur_max, list(acc))
            return
        reserve = sum(d_min[i + 1:])
        for d in range(d_min[i], left - reserve + 1):
            t = time_fn(groups[i].seqs, d)
            acc.append(d)
            rec(i + 1, left - d, max(cur_max, t), acc)
            acc.pop()

    rec(0, n_ranks, 0.0, [])
    ms = (time.perf_counter() - t0) * 1e3
    return Allocation(degrees=best[1], makespan=best[0],
                      ranks_used=sum(best[1]), solver_ms=ms)
