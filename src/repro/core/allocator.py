"""Stage 2 — Optimal Resource Assignment via 2D Dynamic Programming (Alg. 1).

DP[i][j] = minimum achievable makespan for the first i atomic groups using
a total of exactly j ranks:

    DP[i][j] = min_{d in [d_min_i, j - d']} max(DP[i-1][j-d], T(G_i, d))

with d' = sum_{m<i} d_min_m reserving feasibility for the prefix.
Backtracking from the best final state recovers the CP degrees {d_p}.

Complexity O(K' * N^2) — the paper reports <= 86 ms at K'~512, N=64.

The solver is NumPy-vectorized (PR 7). The key index identity: row k of
the DP only has finite states at j in [pre[k-1], N - (pre[-1]-pre[k-1])],
a window of n = N - pre[-1] + 1 states for EVERY row, and the feasible
degrees for group k span [d_min_k, d_min_k + n - 1] — the candidate
matrix M[a][b] = max(DP[k-1][j_b - d_a], T(G_k, d_a)) is square. We
materialize it as a reversed sliding-window (Hankel) view over the
previous DP row padded with +inf (b < a ⇒ +inf), take the columnwise
min for the new row and the columnwise argmin for the backtrack path.
`np.argmin`'s first-occurrence rule reproduces the reference solver's
smallest-degree tie-break exactly, so degrees and makespan are
bit-equal to `allocate_reference` (the retired pure-Python triple
loop, kept as the certification oracle for tests and the host-speed
calibration row in benchmarks).

The cost table T(G_i, d) is built in bulk: when `time_fn` is a bound
`CostModel.group_time`, each group row is one `group_time_vector` call
(per-group aggregates reduced once, Eq. 10 evaluated elementwise over
the whole degree range — bit-identical to the scalar path).

`IncrementalAllocator` adds cross-batch warm starts: consecutive
batches with near-identical bucketed histograms share a prefix of
(group-signature) rows, and only the DP/cost suffix from the first
changed row is re-solved. `allocate_many` solves a lookahead window of
batches in one call with a shared cost-row memo.

Deviation from Alg. 1 as printed: the pseudocode backtracks from
DP[K'][N], i.e. forces sum d_p == N. Because T(G,d) is not monotone in d
(ring comm grows with d for short sequences), using *all* ranks can be
strictly worse than leaving some idle; constraint (6) is an inequality.
We therefore backtrack from argmin_j DP[K'][j]. With `use_all_ranks=True`
the exact printed behaviour is available (and is what the paper's
executor wants when idle ranks would otherwise sit in the DP group
anyway — we default to True but surface both).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Callable, Dict, List, Optional, Sequence as Seq, Tuple

import numpy as np

from .packing import AtomicGroup

INF = float("inf")

# T(G_i, d): estimated execution time of atomic group i at CP degree d.
TimeFn = Callable[[Seq, int], float]


@dataclasses.dataclass
class Allocation:
    degrees: List[int]          # d_p per atomic group (same order as input)
    makespan: float             # max_p T(G_p, d_p)
    ranks_used: int
    solver_ms: float            # cost_ms + dp_ms (total host time)
    cost_ms: float = 0.0        # cost-table build (the time_fn calls)
    dp_ms: float = 0.0          # DP rows + backtrack
    mode: str = "full"          # "full" | "incremental"
    rows_reused: int = 0        # warm-started prefix rows (incremental)


def _group_sig(g: AtomicGroup) -> tuple:
    """Content signature of one atomic group — two groups with equal
    signatures have identical cost rows and identical DP transitions."""
    return (g.d_min, tuple((s.length, s.eta) for s in g.seqs))


def _vector_time_fn(time_fn: TimeFn):
    """Return the (seqs, degrees[]) -> times[] companion of `time_fn`
    when one exists: a bound `group_time` whose owner also exposes
    `group_time_vector` (CostModel and subclasses). Arbitrary callables
    (test lambdas, measured closures) fall back to per-degree calls."""
    owner = getattr(time_fn, "__self__", None)
    if owner is None:
        return None
    if getattr(time_fn, "__func__", None) is not getattr(
            type(owner), "group_time", None):
        return None
    return getattr(owner, "group_time_vector", None)


def _prefix_check(d_min: List[int], pre: List[int], n_ranks: int) -> None:
    if pre[-1] > n_ranks:
        raise ValueError(
            f"infeasible: sum of minimum degrees {pre[-1]} > ranks {n_ranks}")


def _fill_cost_rows(
    cost: np.ndarray,
    groups: Seq[AtomicGroup],
    n_ranks: int,
    d_min: List[int],
    pre: List[int],
    time_fn: TimeFn,
    *,
    start: int = 0,
    memo: Optional[Dict[tuple, np.ndarray]] = None,
    sigs: Optional[List[tuple]] = None,
) -> None:
    """Build cost rows [start, K'): cost[i][d] = T(G_i, d) over the
    feasible degree range. `memo` (keyed by group signature + range)
    shares rows across the instances of a lookahead window."""
    vec = _vector_time_fn(time_fn)
    for i in range(start, len(groups)):
        hi = n_ranks - (pre[-1] - pre[i])
        if hi < d_min[i]:
            continue
        key = None
        if memo is not None:
            key = (sigs[i] if sigs else _group_sig(groups[i]), d_min[i], hi)
            row = memo.get(key)
            if row is not None:
                cost[i, d_min[i]:hi + 1] = row
                continue
        if vec is not None:
            cost[i, d_min[i]:hi + 1] = vec(
                groups[i].seqs, np.arange(d_min[i], hi + 1))
        else:
            cost[i, d_min[i]:hi + 1] = [
                time_fn(groups[i].seqs, d) for d in range(d_min[i], hi + 1)]
        if memo is not None:
            memo[key] = cost[i, d_min[i]:hi + 1].copy()


def _dp_rows(
    dp: np.ndarray,
    path: np.ndarray,
    cost: np.ndarray,
    d_min: List[int],
    pre: List[int],
    n_ranks: int,
    *,
    start: int = 1,
) -> None:
    """Fill DP rows [start, K'] (row k consumes cost row k-1).

    Each row is one square min-max: with n = N - pre[-1] + 1,
    M[a][b] = max(dp[k-1][prev_base + b - a], cost[k-1][d_lo + a]) for
    b >= a (else +inf); dp row = M.min(axis=0), path = argmin + d_lo.
    """
    kp = cost.shape[0]
    n = n_ranks - pre[-1] + 1
    win = np.lib.stride_tricks.sliding_window_view
    pad = np.full(n - 1, INF)
    for k in range(start, kp + 1):
        lo = pre[k - 1]
        prev_base = pre[k - 2] if k >= 2 else 0
        dlo = d_min[k - 1]
        v = dp[k - 1, prev_base:prev_base + n]
        ck = cost[k - 1, dlo:dlo + n]
        # Reversed Hankel view: G[a][b] = v[b-a] for b >= a else +inf.
        g = win(np.concatenate((pad, v)), n)[::-1]
        m = np.maximum(g, ck[:, None])
        dp[k, lo:lo + n] = m.min(axis=0)
        path[k, lo:lo + n] = m.argmin(axis=0) + dlo


def _backtrack(
    dp: np.ndarray,
    path: np.ndarray,
    kp: int,
    n_ranks: int,
    use_all_ranks: bool,
) -> Tuple[List[int], int]:
    if use_all_ranks:
        j_best = n_ranks
        if not dp[kp, j_best] < INF:  # hi_j < N for the last row
            finite = np.nonzero(dp[kp] < INF)[0]
            if finite.size == 0:
                raise ValueError("no feasible allocation")
            j_best = int(finite[-1])
    else:
        j_best = int(np.argmin(dp[kp]))  # first occurrence = smallest j
    degrees = [0] * kp
    p, q = kp, j_best
    while p > 0:
        d = int(path[p, q])
        degrees[p - 1] = d
        p, q = p - 1, q - d
    return degrees, j_best


def _solve(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
    *,
    use_all_ranks: bool,
    sigs: Optional[List[tuple]] = None,
    warm: Optional["SolverState"] = None,
    memo: Optional[Dict[tuple, np.ndarray]] = None,
) -> Tuple[Allocation, "SolverState"]:
    kp = len(groups)
    d_min = [g.d_min for g in groups]
    pre = list(itertools.accumulate(d_min))
    _prefix_check(d_min, pre, n_ranks)
    if sigs is None:
        sigs = [_group_sig(g) for g in groups]

    # Longest reusable prefix: rows of a warm state stay valid while the
    # rank budget, the TOTAL reserved minimum (pre[-1], which shapes every
    # row's feasible window) and the group-signature prefix all match.
    reuse = 0
    if (warm is not None and warm.n_ranks == n_ranks
            and warm.pre[-1] == pre[-1]):
        limit = min(kp, len(warm.sigs))
        while reuse < limit and sigs[reuse] == warm.sigs[reuse]:
            reuse += 1

    t0 = time.perf_counter()
    cost = np.full((kp, n_ranks + 1), INF)
    if reuse:
        cost[:reuse] = warm.cost[:reuse]
    _fill_cost_rows(cost, groups, n_ranks, d_min, pre, time_fn,
                    start=reuse, memo=memo, sigs=sigs)
    t1 = time.perf_counter()
    dp = np.full((kp + 1, n_ranks + 1), INF)
    path = np.zeros((kp + 1, n_ranks + 1), np.int64)
    dp[0, 0] = 0.0
    if reuse:
        dp[1:reuse + 1] = warm.dp[1:reuse + 1]
        path[1:reuse + 1] = warm.path[1:reuse + 1]
    _dp_rows(dp, path, cost, d_min, pre, n_ranks, start=reuse + 1)
    degrees, j_best = _backtrack(dp, path, kp, n_ranks, use_all_ranks)
    t2 = time.perf_counter()

    cost_ms = (t1 - t0) * 1e3
    dp_ms = (t2 - t1) * 1e3
    alloc = Allocation(
        degrees=degrees, makespan=float(dp[kp, j_best]),
        ranks_used=sum(degrees), solver_ms=cost_ms + dp_ms,
        cost_ms=cost_ms, dp_ms=dp_ms,
        mode="incremental" if reuse else "full", rows_reused=reuse)
    state = SolverState(n_ranks=n_ranks, sigs=tuple(sigs), d_min=d_min,
                        pre=pre, cost=cost, dp=dp, path=path)
    return alloc, state


def allocate(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
    *,
    use_all_ranks: bool = True,
) -> Allocation:
    """2D-DP resource allocation (paper Alg. 1), vectorized.

    Drop-in for the original pure-Python solver: bit-equal degrees and
    makespan (see `allocate_reference` and tests/test_allocator.py),
    ~30x less host time at the paper's K'=512, N=64 operating point.
    """
    if len(groups) == 0:
        return Allocation([], 0.0, 0, 0.0)
    alloc, _ = _solve(groups, n_ranks, time_fn, use_all_ranks=use_all_ranks)
    return alloc


@dataclasses.dataclass
class SolverState:
    """Everything needed to warm-start the next solve: per-group content
    signatures plus the cost table and DP/path rows they produced."""

    n_ranks: int
    sigs: Tuple[tuple, ...]
    d_min: List[int]
    pre: List[int]
    cost: np.ndarray            # [K', N+1]
    dp: np.ndarray              # [K'+1, N+1]
    path: np.ndarray            # [K'+1, N+1]


class IncrementalAllocator:
    """Stage-2 solver with cross-batch warm starts (incremental replanning).

    Keeps the last `capacity` solved instances; each call picks the
    stored state sharing the longest group-signature prefix with the new
    instance (the "nearest" previous plan) and re-solves only the cost /
    DP suffix from the first changed row. A large histogram diff means a
    short (possibly empty) shared prefix, which degrades gracefully to
    the full vectorized solve — `Allocation.mode` / `rows_reused` report
    which path ran.

    States are keyed to the cost model identity AND its `cost_version`
    (MeasuredCostModel bumps the version on every record()), so warm
    rows are never reused across cost-model updates. Plans are bit-equal
    to the cold solve by construction: reused rows are the rows the cold
    solve would have recomputed from identical inputs.
    """

    def __init__(self, capacity: int = 4):
        self.capacity = capacity
        self._states: List[Tuple[object, int, SolverState]] = []

    def _token(self, time_fn: TimeFn) -> Tuple[object, int]:
        owner = getattr(time_fn, "__self__", time_fn)
        return owner, getattr(owner, "cost_version", 0)

    def __call__(
        self,
        groups: Seq[AtomicGroup],
        n_ranks: int,
        time_fn: TimeFn,
        *,
        use_all_ranks: bool = True,
    ) -> Allocation:
        if len(groups) == 0:
            return Allocation([], 0.0, 0, 0.0)
        owner, version = self._token(time_fn)
        sigs = [_group_sig(g) for g in groups]
        total = sum(g.d_min for g in groups)

        best_i, best_len = -1, 0
        for i, (o, ver, st) in enumerate(self._states):
            if o is not owner or ver != version or st.n_ranks != n_ranks:
                continue
            if st.pre[-1] != total:
                continue
            p, limit = 0, min(len(sigs), len(st.sigs))
            while p < limit and sigs[p] == st.sigs[p]:
                p += 1
            if p > best_len:
                best_i, best_len = i, p
        warm = self._states[best_i][2] if best_i >= 0 else None

        alloc, state = _solve(groups, n_ranks, time_fn,
                              use_all_ranks=use_all_ranks,
                              sigs=sigs, warm=warm)
        if best_i >= 0 and self._states[best_i][2].sigs == state.sigs:
            self._states.pop(best_i)       # identical instance: replace
        self._states.append((owner, version, state))
        if len(self._states) > self.capacity:
            del self._states[:len(self._states) - self.capacity]
        return alloc


def allocate_many(
    batches: Seq[Seq[AtomicGroup]],
    n_ranks: int,
    time_fn: TimeFn,
    *,
    use_all_ranks: bool = True,
) -> List[Allocation]:
    """Solve a lookahead WINDOW of Stage-2 instances in one call.

    The batched-lookahead contract: cost rows are shared across the
    window through a signature memo (groups recurring at t+1..t+k price
    their degree range exactly once) and each instance additionally
    warm-starts from the nearest already-solved instance. Results are
    bit-equal to calling `allocate` per batch.
    """
    inc = IncrementalAllocator(capacity=max(4, len(batches)))
    memo: Dict[tuple, np.ndarray] = {}
    out: List[Allocation] = []
    for groups in batches:
        if len(groups) == 0:
            out.append(Allocation([], 0.0, 0, 0.0))
            continue
        owner, version = inc._token(time_fn)
        sigs = [_group_sig(g) for g in groups]
        total = sum(g.d_min for g in groups)
        warm = None
        best_len = 0
        for o, ver, st in inc._states:
            if (o is not owner or ver != version or st.n_ranks != n_ranks
                    or st.pre[-1] != total):
                continue
            p, limit = 0, min(len(sigs), len(st.sigs))
            while p < limit and sigs[p] == st.sigs[p]:
                p += 1
            if p > best_len:
                warm, best_len = st, p
        alloc, state = _solve(groups, n_ranks, time_fn,
                              use_all_ranks=use_all_ranks,
                              sigs=sigs, warm=warm, memo=memo)
        inc._states.append((owner, version, state))
        out.append(alloc)
    return out


def allocate_reference(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
    *,
    use_all_ranks: bool = True,
) -> Allocation:
    """The original pure-Python 2D-DP solver, kept verbatim.

    Serves as (a) the certification oracle the vectorized solver must
    match bit-for-bit in tests, and (b) the fixed workload for the
    host-speed calibration row in benchmarks/run.py (its meaning must
    not drift when `allocate` gets faster).
    """
    t0 = time.perf_counter()
    kp = len(groups)
    if kp == 0:
        return Allocation([], 0.0, 0, 0.0)
    d_min = [g.d_min for g in groups]
    pre = list(itertools.accumulate(d_min))          # sum_{i<=k} d_min_i
    _prefix_check(d_min, pre, n_ranks)

    # Memoize T(G_i, d) — the DP probes each (i, d) many times.
    cost: List[List[float]] = []
    for i, g in enumerate(groups):
        row = [INF] * (n_ranks + 1)
        for d in range(d_min[i], n_ranks - (pre[-1] - pre[i]) + 1):
            row[d] = time_fn(g.seqs, d)
        cost.append(row)
    t1 = time.perf_counter()

    dp = [[INF] * (n_ranks + 1) for _ in range(kp + 1)]
    path = [[0] * (n_ranks + 1) for _ in range(kp + 1)]
    dp[0][0] = 0.0
    for k in range(1, kp + 1):
        r_remain = pre[-1] - pre[k - 1]              # ranks still owed to suffix
        lo_j = pre[k - 1]
        hi_j = n_ranks - r_remain
        prev_base = pre[k - 2] if k >= 2 else 0
        dpk, dpk1 = dp[k], dp[k - 1]
        ck, pk = cost[k - 1], path[k]
        for j in range(lo_j, hi_j + 1):
            best, best_d = INF, 0
            for d in range(d_min[k - 1], j - prev_base + 1):
                prev = dpk1[j - d]
                if prev >= best:
                    continue
                c = ck[d] if ck[d] > prev else prev  # max(prev, T(G,d))
                if c < best:
                    best, best_d = c, d
            dpk[j] = best
            pk[j] = best_d

    if use_all_ranks:
        j_best = n_ranks
        if dp[kp][j_best] == INF:   # can happen if hi_j < N for the last row
            j_best = max(j for j in range(n_ranks + 1) if dp[kp][j] < INF)
    else:
        j_best = min(range(n_ranks + 1), key=lambda j: (dp[kp][j], j))
    degrees = [0] * kp
    p, q = kp, j_best
    while p > 0:
        d = path[p][q]
        degrees[p - 1] = d
        p, q = p - 1, q - d
    t2 = time.perf_counter()
    return Allocation(degrees=degrees, makespan=dp[kp][j_best],
                      ranks_used=sum(degrees),
                      solver_ms=(t2 - t0) * 1e3,
                      cost_ms=(t1 - t0) * 1e3, dp_ms=(t2 - t1) * 1e3)


def evaluate_degrees(
    seq_groups: Seq[Seq],
    degrees: Seq[int],
    time_fn: TimeFn,
) -> Allocation:
    """Evaluate a FIXED degree vector — the no-search path.

    Used when the degrees are already known (a cached or replayed plan
    names them), by OracleStrategy.plan_cost to price any plan under
    measured costs, and by tests to certify the DP's reported makespan
    equals the evaluation of its own degree vector.
    """
    t0 = time.perf_counter()
    times = [time_fn(seqs, d) for seqs, d in zip(seq_groups, degrees)]
    ms = (time.perf_counter() - t0) * 1e3
    return Allocation(degrees=list(degrees),
                      makespan=max(times, default=0.0),
                      ranks_used=sum(degrees), solver_ms=ms,
                      cost_ms=ms, dp_ms=0.0)


def allocate_bruteforce(
    groups: Seq[AtomicGroup],
    n_ranks: int,
    time_fn: TimeFn,
) -> Allocation:
    """Exhaustive search over degree vectors — oracle for correctness tests.

    Only tractable for tiny instances (used by tests/property checks to
    certify the DP is exactly optimal for the separable makespan
    objective).
    """
    t0 = time.perf_counter()
    kp = len(groups)
    d_min = [g.d_min for g in groups]
    best: Tuple[float, List[int]] = (INF, [])

    def rec(i: int, left: int, cur_max: float, acc: List[int]):
        nonlocal best
        if cur_max >= best[0]:
            return
        if i == kp:
            best = (cur_max, list(acc))
            return
        reserve = sum(d_min[i + 1:])
        for d in range(d_min[i], left - reserve + 1):
            t = time_fn(groups[i].seqs, d)
            acc.append(d)
            rec(i + 1, left - d, max(cur_max, t), acc)
            acc.pop()

    rec(0, n_ranks, 0.0, [])
    ms = (time.perf_counter() - t0) * 1e3
    return Allocation(degrees=best[1], makespan=best[0],
                      ranks_used=sum(best[1]), solver_ms=ms)
