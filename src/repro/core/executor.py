"""DHP Executor — runs an ExecutionPlan on real devices (§5 workflow (4)).

For each planned CP group the executor:
  1. takes the group's sequences, pads them to a pooled bucket length
     (multiple of the CP degree so the sequence axis shards),
  2. fetches the group's sub-mesh from the GroupPool (the HCCL-pool
     analogue) and the compiled step from the executable pool,
  3. dispatches a shard_map'd forward/backward with Ring-CP attention
     over the `cp` axis.

Groups on disjoint device subsets are dispatched WITHOUT blocking — JAX's
async dispatch executes them concurrently, which is exactly the paper's
concurrent heterogeneous CP groups. Token-count-weighted gradient
averaging across groups reproduces the static single-group gradient
bit-for-bit in expectation (invariant tested in tests/test_executor.py):
dynamic regrouping changes WHERE sequences run, never the math.

This module targets the CPU multi-device demo (model_axis=1, params
replicated). On a TPU pod the same code runs with model_axis=TP and
parameter specs from parallel/sharding.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..data.pipeline import RaggedBatch, padded_batch
from ..models.model import forward
from ..parallel.compat import shard_map
from ..training.optimizer import AdamW
from .group_pool import GroupPool, pow2_bucket
from .scheduler import ExecutionPlan


def _masked_nll(logits, labels, mask):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum(), mask.sum()


class DHPExecutor:
    def __init__(self, cfg: ModelConfig, devices=None, *,
                 model_axis: int = 1, pool: Optional[GroupPool] = None):
        """`pool` shares an externally owned GroupPool (e.g. the
        ClusterSpec's) so meshes/executables are reused across engines;
        by default the executor owns a fresh one over `devices`."""
        if pool is not None:
            self.pool = pool
            self.devices = list(pool.devices.reshape(-1))
        else:
            self.devices = (devices if devices is not None
                            else jax.devices())
            self.pool = GroupPool(self.devices, model_axis)
        self.cfg_cp = cfg.with_(cp_axis="cp", scan_layers=True)
        self.cfg = cfg

    # ------------------------------------------------------------------
    def _group_grad_fn(self, start: int, degree: int, n_seqs: int,
                       bucket: int):
        """Compiled (loss, grads, token_count) for one CP group shape."""
        mesh = self.pool.mesh_for(start, degree)
        cfg = self.cfg_cp

        def build():
            pspec = P()     # params replicated on the sub-mesh (demo TP=1)
            bspec = {k: P(None, "cp") for k in
                     ("tokens", "labels", "mask", "positions")}

            def shard_loss(params, batch):
                logits, aux = forward(params, cfg, batch)
                s, c = _masked_nll(logits, batch["labels"], batch["mask"])
                s = jax.lax.psum(s, "cp")
                c = jax.lax.psum(c, "cp")
                return s / jnp.maximum(c, 1.0)

            def loss_of(params, batch):
                # params enter shard_map replicated (demo TP=1)
                return shard_map(
                    shard_loss, mesh=mesh,
                    in_specs=(pspec, bspec), out_specs=P(),
                )(params, batch)

            def fwd_bwd(params, batch):
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
                return loss, grads

            return jax.jit(fwd_bwd)

        key = ("grad", start, degree, n_seqs, bucket)
        return self.pool.executable_for(key, build)

    # ------------------------------------------------------------------
    def run_plan(self, params, plan: ExecutionPlan, data: RaggedBatch,
                 *, timings: Optional[List[Dict[str, Any]]] = None
                 ) -> Tuple[jax.Array, Any]:
        """Execute every micro-batch of the plan; returns
        (mean loss, token-weighted mean gradient) for the global batch.

        When `timings` (a caller-owned list) is passed, each group is
        executed SYNCHRONOUSLY and a record {seq_ids, degree, tokens,
        seconds, compiled} is appended per group — the measured-cost feed
        for `repro.api.OracleStrategy`. This trades away the concurrent
        dispatch of disjoint groups, so only enable it when measuring."""
        import time as _time
        total_tokens = 0.0
        g_acc = None
        loss_acc = 0.0
        for mb in plan.micro_batches:
            start = 0
            handles = []
            for g in mb.groups:
                if start + g.degree > self.pool.n_replicas:
                    # Defensive fallback for (custom) plans whose
                    # micro-batch oversubscribes the rank budget
                    # (Eq. 6): wrap the cursor so execution proceeds.
                    # Numerics are unaffected, but wrapped groups share
                    # devices with earlier ones and only same-slice
                    # groups serialise — well-formed plans (all built-in
                    # strategies) never take this branch.
                    start = 0
                seqs = [data.by_id(i) for i in g.seq_ids]
                bucket = pow2_bucket(max(len(s) for s in seqs), 64)
                bucket += (-bucket) % g.degree     # shardable over cp
                np_batch = padded_batch(seqs, bucket)
                misses = self.pool.stats.exe_misses
                step = self._group_grad_fn(start, g.degree, len(seqs),
                                           bucket)
                compiled = self.pool.stats.exe_misses > misses
                batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
                n_tok = float(np_batch["mask"].sum())
                if timings is None:
                    handles.append((step(params, batch), n_tok))  # async
                else:
                    t0 = _time.perf_counter()
                    out = jax.block_until_ready(step(params, batch))
                    timings.append({
                        "seq_ids": list(g.seq_ids),
                        "degree": g.degree,
                        "tokens": g.tokens,
                        "bucket": bucket,
                        "seconds": _time.perf_counter() - t0,
                        "compiled": compiled,
                    })
                    handles.append((out, n_tok))
                start += g.degree
            for (loss, grads), n_tok in handles:
                w = n_tok
                total_tokens += w
                loss_acc += float(loss) * w
                g_np = jax.tree.map(
                    lambda a: np.asarray(a, np.float32) * w, grads)
                g_acc = g_np if g_acc is None else jax.tree.map(
                    np.add, g_acc, g_np)
        grads = jax.tree.map(lambda a: jnp.asarray(a / total_tokens),
                             g_acc)
        return jnp.asarray(loss_acc / total_tokens), grads
