"""DHP Executor — runs an ExecutionPlan on real devices (§5 workflow (4)).

For each planned CP group the executor:
  1. flattens the group's sequences into ONE packed token buffer
     (`core/packing.flatten_group`): tokens concatenated, positions
     reset per segment, a segment-id table making attention
     block-diagonal, padding only at the TAIL to a pooled bucket
     (multiple of the CP degree so the sequence axis shards),
  2. fetches the group's sub-mesh from the GroupPool (the HCCL-pool
     analogue) and the compiled step from the executable pool,
  3. dispatches a shard_map'd forward/backward with segment-aware
     Ring-CP attention over the `cp` axis.

The packed path is the load-bearing perf fix (MegaScale-Omni /
Cornstarch's varlen lesson applied to this repo): the per-sequence path
pads every sequence of a group to a pow2 bucket (worst case ~2x wasted
FLOPs on the a1(1+eta)|s|^2 term the cost model optimizes) and keys
executables on ("grad", start, degree, n_seqs, bucket) — the compilation
count grows with the product of group shapes seen. Packing collapses the
key to ("pgrad", start, degree, packed_bucket): n_seqs and the
per-sequence bucket disappear from the compilation space entirely.
(`start` must stay: a shard_map executable closes over its sub-mesh's
physical devices, so groups on different replica slices cannot share a
compiled artifact.) Set `packed=False` for the legacy per-sequence path.

Trade-off to know: block-diagonal attention only SKIPS cross-segment
work in the Pallas kernel (pl.when drops dead tiles). The portable
chunked and ring-CP paths this CPU demo compiles compute the full
(sum|s|)^2 score matrix and mask it — up to ~n_seqs x more attention
FLOPs than per-sequence, traded against the padding waste, the smaller
non-attention token count, and the collapsed executable space. On the
TPU target (attn_impl="pallas") the skip is real and packing wins
outright; bench_end_to_end.run_packed reports both step_time and
padding so the trade stays visible.

Groups on disjoint device subsets are dispatched WITHOUT blocking — JAX's
async dispatch executes them concurrently, which is exactly the paper's
concurrent heterogeneous CP groups. Token-count-weighted gradient
averaging across groups reproduces the static single-group gradient
bit-for-bit in expectation (invariant tested in tests/test_parallel.py):
dynamic regrouping changes WHERE sequences run, never the math.

This module targets the CPU multi-device demo (model_axis=1, params
replicated). On a TPU pod the same code runs with model_axis=TP and
parameter specs from parallel/sharding.py.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..data.pipeline import RaggedBatch, padded_batch
from ..models.model import forward
from ..obs.trace import get_tracer
from ..parallel.compat import shard_map
from ..training.optimizer import AdamW
from .group_pool import GroupPool
from .packing import MODALITY_CLASSES, flatten_group
from .scheduler import ExecutionPlan

#: families whose attention layers support block-diagonal segment masks;
#: recurrent state (ssm/hybrid) crosses segment boundaries, and
#: vlm/audio batches carry extra modal inputs the flattener doesn't pack.
PACKABLE_FAMILIES = ("dense", "moe")


def _token_nll(logits, labels):
    """Per-position next-token NLL (no masking applied)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return logz - gold


def _masked_nll(logits, labels, mask):
    nll = _token_nll(logits, labels) * mask
    return nll.sum(), mask.sum()


class DHPExecutor:
    def __init__(self, cfg: ModelConfig, devices=None, *,
                 model_axis: int = 1, pool: Optional[GroupPool] = None,
                 packed: Optional[bool] = None):
        """`pool` shares an externally owned GroupPool (e.g. the
        ClusterSpec's) so meshes/executables are reused across engines;
        by default the executor owns a fresh one over `devices`.

        `packed` selects the packed varlen execution path (default: on
        for families in PACKABLE_FAMILIES, off otherwise)."""
        if pool is not None:
            self.pool = pool
            self.devices = list(pool.devices.reshape(-1))
        else:
            self.devices = (devices if devices is not None
                            else jax.devices())
            self.pool = GroupPool(self.devices, model_axis)
        self.cfg_cp = cfg.with_(cp_axis="cp", scan_layers=True)
        self.cfg = cfg
        if packed is None:
            packed = cfg.family in PACKABLE_FAMILIES
        if packed and cfg.family not in PACKABLE_FAMILIES:
            raise ValueError(
                f"packed execution unsupported for family {cfg.family!r}"
                f" (needs segment-maskable attention + token-only batch)")
        self.packed = packed
        #: padding/compile telemetry of the most recent run_plan()
        #: (+ "modality_loss" sub-dict for span-bearing runs)
        self.last_run_stats: Dict[str, Any] = {}
        #: executable-pool keys dispatched by the most recent run_plan(),
        #: in dispatch order — the replay bit-identity witness (a plan
        #: saved with --save-plans must reproduce these exactly).
        self.last_exe_keys: List[Tuple] = []

    # ------------------------------------------------------------------
    def _build_grad_fn(self, mesh, with_spans: bool):
        """(loss, grads[, modality nll table]) step over a sub-mesh;
        batch seq-axis sharded.

        `with_spans` adds the modality_ids table (the mixed-mask
        bidirectional-block table), the `loss_mask` (labels inside
        bidirectional spans carry no NLL — they attend their own
        future) and the `modality_classes` label table to the sharded
        batch — only span-bearing groups compile/run the span-masked
        attention + masked-loss path; pure-causal groups keep the
        pre-span executable (and its exact numerics). Span-bearing
        steps return a [n_classes, 2] (nll_sum, label_count) aux table
        per MODALITY_CLASSES entry, reduced over the cp axis."""
        cfg = self.cfg_cp

        def build():
            pspec = P()     # params replicated on the sub-mesh (demo TP=1)
            keys = ("tokens", "labels", "mask", "positions")
            if with_spans:
                keys = keys + ("modality_ids", "loss_mask",
                               "modality_classes")
            if self.packed:
                keys = keys + ("segment_ids",)
            bspec = {k: P(None, "cp") for k in keys}

            def shard_loss(params, batch):
                logits, _ = forward(params, cfg, batch)
                if not with_spans:
                    s, c = _masked_nll(logits, batch["labels"],
                                       batch["mask"])
                    s = jax.lax.psum(s, "cp")
                    c = jax.lax.psum(c, "cp")
                    return s / jnp.maximum(c, 1.0)
                nll = _token_nll(logits, batch["labels"])
                lm = batch["loss_mask"]
                s = jax.lax.psum((nll * lm).sum(), "cp")
                c = jax.lax.psum(lm.sum(), "cp")
                # per-modality NLL over ALL valid labels (base mask):
                # classes excluded from the training loss still report
                cls = batch["modality_classes"]
                rows = []
                for k in range(len(MODALITY_CLASSES)):
                    mk = batch["mask"] * (cls == k)
                    rows.append(jnp.stack([(nll * mk).sum(), mk.sum()]))
                aux = jax.lax.psum(jnp.stack(rows), "cp")
                # telemetry only — a symbolic-Zero tangent for aux
                # would not transpose through shard_map
                return s / jnp.maximum(c, 1.0), jax.lax.stop_gradient(aux)

            def loss_of(params, batch):
                # params enter shard_map replicated (demo TP=1)
                out_specs = (P(), P()) if with_spans else P()
                return shard_map(
                    shard_loss, mesh=mesh,
                    in_specs=(pspec, bspec), out_specs=out_specs,
                )(params, batch)

            def fwd_bwd(params, batch):
                if with_spans:
                    (loss, aux), grads = jax.value_and_grad(
                        loss_of, has_aux=True)(params, batch)
                    return loss, grads, aux
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
                return loss, grads

            return jax.jit(fwd_bwd)

        return build

    def _group_grad_fn(self, start: int, degree: int, n_seqs: int,
                       bucket: int, with_spans: bool
                       ) -> Tuple[Any, bool, Tuple]:
        """Per-sequence-padded step for one CP group shape (legacy path:
        the executable key still depends on n_seqs)."""
        mesh = self.pool.mesh_for(start, degree)
        key = ("grad", start, degree, n_seqs, bucket) \
            + (("mm",) if with_spans else ())
        exe, miss = self.pool.executable_for(
            key, self._build_grad_fn(mesh, with_spans))
        return exe, miss, key

    def _packed_grad_fn(self, start: int, degree: int, bucket: int,
                        with_spans: bool) -> Tuple[Any, bool, Tuple]:
        """Packed varlen step: ONE [1, bucket] buffer regardless of how
        many sequences the group holds — n_seqs is gone from the key.
        Span-bearing groups get a distinct "mm" executable (their batch
        carries the modality table); causal groups keep the exact
        pre-span key tuple."""
        mesh = self.pool.mesh_for(start, degree)
        key = ("pgrad", start, degree, bucket) \
            + (("mm",) if with_spans else ())
        exe, miss = self.pool.executable_for(
            key, self._build_grad_fn(mesh, with_spans))
        return exe, miss, key

    # ------------------------------------------------------------------
    def _group_batch(self, seqs, degree: int, spans=None):
        """(np_batch, real_tokens, padded_tokens, bucket) for one group.

        `spans` (optional, parallel to `seqs`) carries each sequence's
        ModalitySpan layout; both paths emit the same per-sequence
        modality table, so packed and per-sequence execution apply the
        identical mixed mask."""
        if self.packed:
            total = sum(len(s) for s in seqs)
            bucket = self.pool.bucket(total)
            bucket += (-bucket) % degree       # shardable over cp
            np_batch, cu = flatten_group(seqs, bucket, spans=spans)
            return np_batch, int(cu[-1]), bucket, bucket
        bucket = self.pool.bucket(max(len(s) for s in seqs))
        bucket += (-bucket) % degree           # shardable over cp
        np_batch = padded_batch(seqs, bucket, spans=spans)
        real = sum(min(len(s), bucket) for s in seqs)
        return np_batch, real, len(seqs) * bucket, bucket

    # ------------------------------------------------------------------
    def run_plan(self, params, plan: ExecutionPlan, data: RaggedBatch,
                 *, timings: Optional[List[Dict[str, Any]]] = None
                 ) -> Tuple[jax.Array, Any]:
        """Execute every micro-batch of the plan; returns
        (mean loss, token-weighted mean gradient) for the global batch.

        When `timings` (a caller-owned list) is passed, each group is
        executed SYNCHRONOUSLY and a record {seq_ids, degree, tokens,
        bucket, seconds, compiled, real_tokens, padded_tokens,
        padding_efficiency} is appended per group — the measured-cost
        feed for `repro.api.OracleStrategy` (padding fields let it see
        TRUE per-token costs, not padded-shape artefacts). This trades
        away the concurrent dispatch of disjoint groups, so only enable
        it when measuring.

        `self.last_run_stats` always aggregates {real_tokens,
        padded_tokens, padding_efficiency, exe_misses, groups} for the
        run — the benchmark/CI telemetry feed. Span-bearing runs add
        "modality_loss": {class name: mean NLL} over every class that
        had at least one valid label (classes masked OUT of the
        training loss, e.g. bidirectional vision spans, still report)."""
        import time as _time
        tr = get_tracer()
        t_run = _time.perf_counter()
        total_tokens = 0.0
        g_acc = None
        loss_acc = 0.0
        aux_acc = None       # [n_classes, 2] (nll_sum, label_count)
        agg: Dict[str, Any] = {"real_tokens": 0, "padded_tokens": 0,
                               "exe_misses": 0, "groups": 0}
        # Rank slots come from the plan IR itself (including the
        # defensive wrap for oversubscribed micro-batches) so executor,
        # GroupDelta diffing and replay equality all agree on which rank
        # slice a group runs on.
        slots = iter(plan.group_slots(self.pool.n_replicas))
        self.last_exe_keys = []
        spans_by_id = (data.spans_by_id()
                       if hasattr(data, "spans_by_id") else {})
        for mb in plan.micro_batches:
            handles = []
            for g in mb.groups:
                mi, gi, start, _ = next(slots)
                seqs = [data.by_id(i) for i in g.seq_ids]
                spans = ([spans_by_id.get(i) for i in g.seq_ids]
                         if spans_by_id else None)
                np_batch, real, padded, bucket = self._group_batch(
                    seqs, g.degree, spans=spans)
                with_spans = "modality_ids" in np_batch
                if self.packed:
                    step, compiled, key = self._packed_grad_fn(
                        start, g.degree, bucket, with_spans)
                else:
                    step, compiled, key = self._group_grad_fn(
                        start, g.degree, len(seqs), bucket, with_spans)
                self.last_exe_keys.append(key)
                batch = {k: jnp.asarray(v) for k, v in np_batch.items()}
                # weight groups by LOSS tokens when a loss mask exists —
                # bidirectional-span labels carry no NLL, so counting
                # them would dilute the span-bearing groups' gradients
                n_tok = float(np_batch.get(
                    "loss_mask", np_batch["mask"]).sum())
                agg["real_tokens"] += real
                agg["padded_tokens"] += padded
                agg["exe_misses"] += int(compiled)
                agg["groups"] += 1
                if timings is None:
                    t0 = _time.perf_counter()
                    handles.append((step(params, batch), n_tok))  # async
                    if tr.enabled:
                        # host-side dispatch cost only: the device work
                        # runs asynchronously and is not observable
                        # per group on this path
                        tr.complete("dispatch", t0,
                                    _time.perf_counter() - t0, "exec",
                                    args={"mb": mi, "group": gi,
                                          "degree": g.degree,
                                          "start_rank": start})
                else:
                    t0 = _time.perf_counter()
                    out = jax.block_until_ready(step(params, batch))
                    dt = _time.perf_counter() - t0
                    timings.append({
                        "seq_ids": list(g.seq_ids),
                        "degree": g.degree,
                        "tokens": g.tokens,
                        "bucket": bucket,
                        "seconds": dt,
                        "compiled": compiled,
                        "real_tokens": real,
                        "padded_tokens": padded,
                        "padding_efficiency": real / max(padded, 1),
                    })
                    if tr.enabled:
                        # measured group time becomes ONE span on the
                        # track of every rank the group occupies — the
                        # per-rank timeline the straggler analytics read
                        for rank in range(start, start + g.degree):
                            tr.rank_span(
                                "execute", rank, t0, dt,
                                args={"mb": mi, "group": gi,
                                      "degree": g.degree,
                                      "tokens": g.tokens,
                                      "compiled": compiled})
                    handles.append((out, n_tok))
            t_collect = _time.perf_counter()
            for out, n_tok in handles:
                loss, grads = out[0], out[1]
                if len(out) > 2:           # span-bearing: modality aux
                    a = np.asarray(out[2], np.float64)
                    aux_acc = a if aux_acc is None else aux_acc + a
                w = n_tok
                total_tokens += w
                loss_acc += float(loss) * w
                g_np = jax.tree.map(
                    lambda a: np.asarray(a, np.float32) * w, grads)
                g_acc = g_np if g_acc is None else jax.tree.map(
                    np.add, g_acc, g_np)
            if tr.enabled:
                # draining the handles forces the device sync for this
                # micro-batch — the wave barrier
                tr.complete("collect", t_collect,
                            _time.perf_counter() - t_collect, "exec",
                            args={"groups": len(handles)})
        agg["padding_efficiency"] = (
            agg["real_tokens"] / max(agg["padded_tokens"], 1))
        if aux_acc is not None:
            agg["modality_loss"] = {
                name: float(aux_acc[k, 0] / aux_acc[k, 1])
                for k, name in enumerate(MODALITY_CLASSES)
                if aux_acc[k, 1] > 0}
        self.last_run_stats = agg
        denom = max(total_tokens, 1.0)
        grads = jax.tree.map(lambda a: jnp.asarray(a / denom), g_acc)
        if tr.enabled:
            tr.complete("run_plan", t_run,
                        _time.perf_counter() - t_run, "exec",
                        args={"groups": agg["groups"],
                              "exe_misses": agg["exe_misses"],
                              "measured": timings is not None})
        return jnp.asarray(loss_acc / denom), grads
