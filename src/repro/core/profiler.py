"""Profiler — fits the cost-model coefficients (§5 Implementation (3)).

Before training, the paper's Profiler runs forward/backward passes over a
grid of (sequence length, CP degree) and fits the functional relationship
T(s, d). We reproduce that:

  * `collect(measure_fn, lengths, degrees)` gathers samples by calling a
    user measurement function (a real timed JAX step on CPU in tests, or
    the analytic TPU model in the simulator).
  * `fit()` solves the least-squares system for (a1, a2, b1) on the
    compute samples and (a3, b2) on the comm samples.
  * `predict(seqs, d)` then evaluates Eq. (10), and `error(samples)`
    reports mean absolute percentage error — the Table-3 reproduction.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence as Seq, Tuple

import numpy as np

from .cost_model import CostCoeffs, CostModel, Hardware, SeqInfo


@dataclasses.dataclass
class Sample:
    length: int
    degree: int
    eta: float
    time_s: float


MeasureFn = Callable[[int, int, float], float]   # (length, degree, eta) -> s


class Profiler:
    """Fits CostCoeffs from timed samples; serves predictions to the DP."""

    def __init__(self, hw: Hardware | None = None,
                 m_token: float = 1.0, m_ms: float = 0.0):
        self.hw = hw or Hardware()
        self.m_token = m_token
        self.m_ms = m_ms
        self.samples: List[Sample] = []
        self.coeffs: CostCoeffs | None = None

    # ------------------------------------------------------------------
    def collect(self, measure_fn: MeasureFn,
                lengths: Seq[int], degrees: Seq[int],
                etas: Seq[float] = (0.0,)) -> None:
        for L in lengths:
            for d in degrees:
                for eta in etas:
                    self.samples.append(
                        Sample(L, d, eta, measure_fn(L, d, eta)))

    def add_sample(self, length: int, degree: int, eta: float,
                   time_s: float) -> None:
        self.samples.append(Sample(length, degree, eta, time_s))

    # ------------------------------------------------------------------
    def fit(self) -> CostCoeffs:
        """Least squares on  T ~ a1*(1+eta)L^2/d + a2*L/d + b1
                               + [a3*L*(d-1)/(d*v) + b2]_{d>1}
        with the ring-overlap min() term linearized by assuming compute
        dominates (true for the profiling grid we choose: long sequences).
        """
        if not self.samples:
            raise RuntimeError("no samples collected")
        rows, y = [], []
        for s in self.samples:
            v = self.hw.ring_bandwidth(s.degree)
            comm = (s.length * (s.degree - 1) / s.degree / v
                    if s.degree > 1 else 0.0)
            rows.append([
                (1 + s.eta) * s.length ** 2 / s.degree,   # a1
                s.length / s.degree,                       # a2
                1.0,                                       # b1 (+b2 folded)
                comm,                                      # a3
            ])
            y.append(s.time_s)
        A = np.asarray(rows)
        try:
            from scipy.optimize import nnls
            coef, _ = nnls(A, np.asarray(y))
        except ImportError:     # pragma: no cover
            coef, *_ = np.linalg.lstsq(A, np.asarray(y), rcond=None)
        a1, a2, b1, a3 = [max(float(c), 0.0) for c in coef]
        self.coeffs = CostCoeffs(a1=a1, a2=a2, b1=b1, a3=a3, b2=0.0,
                                 m_token=self.m_token, m_ms=self.m_ms)
        return self.coeffs

    # ------------------------------------------------------------------
    def cost_model(self) -> CostModel:
        if self.coeffs is None:
            self.fit()
        return CostModel(self.coeffs, self.hw)

    def predict(self, length: int, degree: int, eta: float = 0.0) -> float:
        cm = self.cost_model()
        # overlap credit applies only where comm exists
        return cm.group_time([SeqInfo(length=length, eta=eta)], degree)

    def error(self, holdout: Seq[Sample] | None = None) -> float:
        """Mean absolute percentage error of the fit (Table 3)."""
        data = list(holdout) if holdout is not None else self.samples
        errs = []
        for s in data:
            pred = self.predict(s.length, s.degree, s.eta)
            if s.time_s > 0:
                errs.append(abs(pred - s.time_s) / s.time_s)
        return 100.0 * float(np.mean(errs))


def profiling_grid(max_len: int) -> Tuple[List[int], List[int]]:
    """The (length, degree) grid the paper's profile function sweeps."""
    lengths, L = [], 512
    while L <= max_len:
        lengths.append(L)
        L *= 2
    return lengths, [1, 2, 3, 4, 6, 8]
