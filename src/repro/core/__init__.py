"""DHP core — the paper's contribution: dynamic hybrid parallelism.

Public API:
  CostModel / CostCoeffs / SeqInfo / Hardware   (Eqs. 7-10)
  pack_sequences / AtomicGroup                  (Stage 1, BFD)
  allocate / allocate_bruteforce                (Stage 2, 2D-DP, Alg. 1)
  DHPScheduler / static_plan / ExecutionPlan    (Fig. 3 workflow)
  Profiler                                      (coefficient fitting)
  ClusterSimulator / end_to_end_table           (paper-table reproduction)
"""
from .allocator import (Allocation, IncrementalAllocator, allocate,
                        allocate_bruteforce, allocate_many,
                        allocate_reference, evaluate_degrees)
from .cost_model import (CostCoeffs, CostModel, Hardware, MMSequence,
                         ModalitySpan, SeqInfo, analytic_coeffs,
                         as_seq_infos, slice_spans, spans_eta,
                         synthesize_spans)
from .dataset_profiles import PROFILES, DatasetProfile, get_profile
from .distributions import DATASETS, sample_batch, sample_mm_batch
from .group_pool import (BUCKET_LADDERS, GroupPool, make_bucket_fn,
                         pow2_bucket)
from .packing import (AtomicGroup, flatten_group, pack_sequences,
                      packing_efficiency, validate_packing)
from .profiler import Profiler, profiling_grid
from .scheduler import (PLAN_IR_VERSION, DHPScheduler, ExecutionPlan,
                        GroupDelta, GroupPlan, MicroBatchPlan,
                        MicroBatchPlanner, PlanCache,
                        PlanValidationError, diff_plans, load_plans,
                        plans_from_json, plans_to_json, save_plans,
                        static_plan)
from .simulator import ClusterSimulator, end_to_end_table, scaling_table

__all__ = [
    "Allocation", "IncrementalAllocator", "allocate",
    "allocate_bruteforce", "allocate_many", "allocate_reference",
    "evaluate_degrees",
    "CostCoeffs", "CostModel", "Hardware", "SeqInfo", "analytic_coeffs",
    "MMSequence", "ModalitySpan", "as_seq_infos", "slice_spans",
    "spans_eta", "synthesize_spans",
    "DatasetProfile", "PROFILES", "get_profile",
    "DATASETS", "sample_batch", "sample_mm_batch",
    "AtomicGroup", "pack_sequences", "validate_packing",
    "flatten_group", "packing_efficiency",
    "BUCKET_LADDERS", "GroupPool", "make_bucket_fn", "pow2_bucket",
    "Profiler", "profiling_grid",
    "DHPScheduler", "ExecutionPlan", "GroupPlan", "MicroBatchPlan",
    "MicroBatchPlanner", "static_plan",
    "PLAN_IR_VERSION", "GroupDelta", "PlanCache",
    "PlanValidationError", "diff_plans",
    "plans_to_json", "plans_from_json", "save_plans", "load_plans",
    "ClusterSimulator", "end_to_end_table", "scaling_table",
]
