"""Dynamic group management & pooling (§5 Implementation (1)).

The paper pools HCCL communication groups because creating them per batch
is expensive. The JAX analogue: the expensive per-configuration artifacts
are `jax.sharding.Mesh` objects over device subsets and, above all,
*compiled executables* (XLA compilation replaces NCCL/HCCL group setup as
the dominant reconfiguration cost). `GroupPool` caches both:

  * `mesh_for(start, degree)`   — a (cp, model)-axis mesh over the device
    slice [start, start+degree) of the replica grid;
  * `executable_for(key, build)`— memoized compiled step functions keyed
    by (degree, padded sequence bucket, microbatch rows, ...).

Sequence lengths are bucketed (pow-2 padding by default) so the number of
distinct executables stays bounded over a training run — mirroring the
paper's observation that "the total number of unique groups required is
limited".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Hashable, Tuple

import numpy as np


def pow2_bucket(n: int, minimum: int = 128) -> int:
    """Smallest power-of-two >= n (>= minimum) — the padding bucket."""
    b = minimum
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class PoolStats:
    mesh_hits: int = 0
    mesh_misses: int = 0
    exe_hits: int = 0
    exe_misses: int = 0


class GroupPool:
    """Cache of sub-meshes and compiled executables for CP groups."""

    def __init__(self, devices, model_axis: int = 1,
                 axis_names: Tuple[str, str] = ("cp", "model")):
        """`devices`: flat list of devices, viewed as a
        (n_replicas, model_axis) grid. model_axis=1 means a replica is a
        single device (TP folded away — the CPU-demo case)."""
        self.devices = np.asarray(devices).reshape(-1, model_axis)
        self.n_replicas = self.devices.shape[0]
        self.model_axis = model_axis
        self.axis_names = axis_names
        self._meshes: Dict[Tuple[int, int], Any] = {}
        self._exes: Dict[Hashable, Any] = {}
        self.stats = PoolStats()

    # ------------------------------------------------------------------
    def mesh_for(self, start: int, degree: int):
        """Mesh over replicas [start, start+degree) — a CP ring of size
        `degree` x the static model (TP) axis."""
        from jax.sharding import Mesh
        key = (start, degree)
        if key in self._meshes:
            self.stats.mesh_hits += 1
            return self._meshes[key]
        self.stats.mesh_misses += 1
        assert start + degree <= self.n_replicas, (start, degree)
        devs = self.devices[start:start + degree]
        mesh = Mesh(devs, self.axis_names)
        self._meshes[key] = mesh
        return mesh

    # ------------------------------------------------------------------
    def executable_for(self, key: Hashable, build: Callable[[], Any]):
        """Memoized compile: `build()` is invoked only on pool miss."""
        if key in self._exes:
            self.stats.exe_hits += 1
            return self._exes[key]
        self.stats.exe_misses += 1
        exe = build()
        self._exes[key] = exe
        return exe

    def __len__(self) -> int:
        return len(self._exes)
