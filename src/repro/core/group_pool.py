"""Dynamic group management & pooling (§5 Implementation (1)).

The paper pools HCCL communication groups because creating them per batch
is expensive. The JAX analogue: the expensive per-configuration artifacts
are `jax.sharding.Mesh` objects over device subsets and, above all,
*compiled executables* (XLA compilation replaces NCCL/HCCL group setup as
the dominant reconfiguration cost). `GroupPool` caches both:

  * `mesh_for(start, degree)`   — a (cp, model)-axis mesh over the device
    slice [start, start+degree) of the replica grid;
  * `executable_for(key, build)`— memoized compiled step functions keyed
    by (degree, padded bucket, ...); returns `(exe, was_miss)` so callers
    can attribute compile time to the group that actually triggered it.

Sequence lengths are bucketed so the number of distinct executables stays
bounded over a training run — mirroring the paper's observation that "the
total number of unique groups required is limited". The bucket ladder is
configurable (`make_bucket_fn`): pow2 (default, fewest executables,
worst-case 2x padding), geometric 1.25x (worst-case 1.25x padding, more
rungs), or multiple-of-256 (near-constant absolute padding, most rungs).
The executable cache is optionally LRU-capped (`max_executables`) so long
heterogeneous runs cannot grow host memory without bound.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict
from functools import partial
from typing import Any, Callable, Dict, Hashable, Optional, Tuple, Union

import numpy as np

from ..obs.trace import get_tracer


def pow2_bucket(n: int, minimum: int = 128) -> int:
    """Smallest power-of-two >= n (>= minimum) — the padding bucket."""
    b = minimum
    while b < n:
        b *= 2
    return b


def geometric_bucket(n: int, minimum: int = 128,
                     ratio: float = 1.25) -> int:
    """Smallest rung of a geometric `ratio` ladder >= n (8-aligned).

    Worst-case padding overhead is `ratio` (vs 2x for pow2) at the cost
    of log_ratio / log_2 more distinct rungs (~3.1x for ratio=1.25)."""
    b = minimum
    while b < n:
        b = int(math.ceil(b * ratio / 8.0)) * 8
    return b


def multiple_bucket(n: int, multiple: int = 256) -> int:
    """Round up to a multiple — near-constant absolute padding; the rung
    count grows linearly with the longest length seen."""
    return max(multiple, ((n + multiple - 1) // multiple) * multiple)


BUCKET_LADDERS = ("pow2", "geometric", "mult256")


def make_bucket_fn(kind: Union[str, Callable[[int], int]] = "pow2",
                   minimum: int = 64) -> Callable[[int], int]:
    """Resolve a bucket-ladder name (or pass a callable through)."""
    if callable(kind):
        return kind
    if kind == "pow2":
        return partial(pow2_bucket, minimum=minimum)
    if kind == "geometric":
        return partial(geometric_bucket, minimum=minimum)
    if kind == "mult256":
        return multiple_bucket
    raise ValueError(
        f"unknown bucket ladder {kind!r}; expected one of "
        f"{BUCKET_LADDERS} or a callable")


@dataclasses.dataclass
class PoolStats:
    mesh_hits: int = 0
    mesh_misses: int = 0
    exe_hits: int = 0
    exe_misses: int = 0
    exe_evictions: int = 0
    #: group slots (re)created because a GroupDelta named them as new or
    #: resized relative to the previous plan (see `reconfigure`).
    groups_reconfigured: int = 0


class GroupPool:
    """Cache of sub-meshes and compiled executables for CP groups."""

    def __init__(self, devices, model_axis: int = 1,
                 axis_names: Tuple[str, str] = ("cp", "model"),
                 bucket_fn: Union[str, Callable[[int], int]] = "pow2",
                 max_executables: Optional[int] = None):
        """`devices`: flat list of devices, viewed as a
        (n_replicas, model_axis) grid. model_axis=1 means a replica is a
        single device (TP folded away — the CPU-demo case).

        `bucket_fn`: padding-bucket ladder, a name from BUCKET_LADDERS
        or a callable n -> bucket. `max_executables`: LRU cap on the
        executable cache (None = unbounded)."""
        self.devices = np.asarray(devices).reshape(-1, model_axis)
        self.n_replicas = self.devices.shape[0]
        self.model_axis = model_axis
        self.axis_names = axis_names
        self.bucket_fn = make_bucket_fn(bucket_fn)
        self.max_executables = max_executables
        self._meshes: Dict[Tuple[int, int], Any] = {}
        self._exes: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.stats = PoolStats()

    # ------------------------------------------------------------------
    def bucket(self, n: int) -> int:
        """Padding bucket for `n` tokens under the pool's ladder."""
        return self.bucket_fn(n)

    # ------------------------------------------------------------------
    def mesh_for(self, start: int, degree: int):
        """Mesh over replicas [start, start+degree) — a CP ring of size
        `degree` x the static model (TP) axis."""
        from jax.sharding import Mesh
        key = (start, degree)
        if key in self._meshes:
            self.stats.mesh_hits += 1
            return self._meshes[key]
        self.stats.mesh_misses += 1
        assert start + degree <= self.n_replicas, (start, degree)
        devs = self.devices[start:start + degree]
        mesh = Mesh(devs, self.axis_names)
        self._meshes[key] = mesh
        return mesh

    # ------------------------------------------------------------------
    def executable_for(self, key: Hashable,
                       build: Callable[[], Any]) -> Tuple[Any, bool]:
        """Memoized compile: `build()` is invoked only on pool miss.

        Returns `(exe, was_miss)` — was_miss tells the caller whether
        THIS lookup compiled (stats deltas misattribute when several
        groups interleave in one run_plan). LRU: hits refresh recency;
        over-cap inserts evict the least-recently-used executable."""
        if key in self._exes:
            self.stats.exe_hits += 1
            self._exes.move_to_end(key)
            return self._exes[key], False
        self.stats.exe_misses += 1
        # span name is "exe_build", not "compile": jit() is lazy, XLA
        # compilation itself lands in the first execution (the timing
        # record's `compiled` flag / rank-span arg carries that)
        with get_tracer().span("exe_build", "pool",
                               args={"key": repr(key)}):
            exe = build()
        self._exes[key] = exe
        if (self.max_executables is not None
                and len(self._exes) > self.max_executables):
            self._exes.popitem(last=False)
            self.stats.exe_evictions += 1
        return exe, True

    # ------------------------------------------------------------------
    def reconfigure(self, delta) -> Dict[str, int]:
        """Apply a plan's GroupDelta: pre-create meshes for the slots the
        delta names as `created`/`resized` and count `reused` slots as
        zero-cost pool hits — the pool consumes the delta instead of
        re-deriving every group from scratch per plan (§5 (1)).

        Returns {created, resized, reused} counts for telemetry."""
        if delta is None:
            return {"created": 0, "resized": 0, "reused": 0}
        for start, degree in list(delta.created) + list(delta.resized):
            if start + degree <= self.n_replicas:
                self.mesh_for(start, degree)
        self.stats.groups_reconfigured += delta.n_reconfigured
        return {"created": len(delta.created),
                "resized": len(delta.resized),
                "reused": len(delta.reused)}

    def __len__(self) -> int:
        return len(self._exes)
