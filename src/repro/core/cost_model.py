"""DHP cost model — Eqs. (7)-(10) of the paper.

Memory  (Eq. 7):  M(C_p)  = sum_k A_kp |s_k| * M_token + M_ms
Compute (Eq. 8):  T_cp    = sum_k A_kp (a1 (1+eta_k) |s_k|^2 + a2 |s_k|) + b1
Comm    (Eq. 9):  T_cm    = (1/v_p) sum_k A_kp a3 |s_k| + b2
Total   (Eq.10):  T       = T_cp + T_cm - min(T_cpa, T_cma)

The per-rank execution time under CP degree d divides the compute terms
by d (ring CP splits the sequence evenly); the ring communication volume
per rank is ~|s|*(d-1)/d (each rank forwards its KV shard d-1 hops), which
the paper approximates as linear in |s| (Eq. 9 has no explicit d) — we
keep the exact (d-1)/d factor, which degenerates to the paper's form for
large d and to zero for d=1 (no ring needed), matching the paper's claim
that short sequences at low degree avoid redundant communication.

eta_k is the *mask efficiency factor*: the extra attention compute from
full-attention (vision) tokens relative to causal. eta=0 → pure causal,
eta=1 → pure full attention (2x the causal FLOPs).

Since PR 5, eta is no longer an asserted scalar: multimodal sequences
are described structurally as `MMSequence`s of `ModalitySpan`s (a causal
text stream with bidirectional vision/audio blocks embedded in it —
the mask the paper's Eq. 8 actually costs), and eta is DERIVED from the
span geometry. With the causal half-mask folded into a1 (causal over
|s| tokens ~ |s|^2/2 score pairs), a bidirectional span of m tokens
adds m^2/2 extra pairs on top of its causal share, so

    eta = sum_b m_b^2 / |s|^2        over bidirectional spans b.

One span covering the whole sequence gives eta=1 (pure full attention);
no bidirectional spans give eta=0 (pure causal) — the two anchors of
the scalar model. `SeqInfo` remains the planner currency: plain
`SeqInfo(length, eta)` construction still works everywhere, and a
span-bearing `SeqInfo` (the `MMSequence.seq_info` view) recomputes its
`length`/`eta` from the spans so structure is the single source of
truth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence as Seq, Tuple

import numpy as np

#: valid ModalitySpan.attn values
ATTN_CAUSAL = "causal"
ATTN_BIDIRECTIONAL = "bidirectional"


@dataclasses.dataclass(frozen=True)
class ModalitySpan:
    """A contiguous run of same-modality tokens inside one sequence.

    `start` is the token offset within the sequence; `attn` declares how
    the span's tokens attend *within the span*: "causal" (text) or
    "bidirectional" (vision frames / audio windows — the blocks that
    make Eq. 8's eta non-zero). Across spans the stream stays causal.
    """

    modality: str                   # "text" | "vision" | "audio" | ...
    start: int
    length: int
    attn: str = ATTN_CAUSAL

    def __post_init__(self):
        if self.length <= 0:
            raise ValueError(f"span length must be positive: {self}")
        if self.attn not in (ATTN_CAUSAL, ATTN_BIDIRECTIONAL):
            raise ValueError(f"unknown span attn {self.attn!r}")

    @property
    def end(self) -> int:
        return self.start + self.length

    def to_json(self) -> list:
        return [self.modality, self.start, self.length, self.attn]

    @classmethod
    def from_json(cls, obj) -> "ModalitySpan":
        return cls(modality=str(obj[0]), start=int(obj[1]),
                   length=int(obj[2]), attn=str(obj[3]))


def spans_length(spans: Seq[ModalitySpan]) -> int:
    return sum(s.length for s in spans)


def spans_eta(spans: Seq[ModalitySpan]) -> float:
    """Eq. 8's mask-efficiency factor derived from span geometry:
    sum of squared bidirectional-span lengths over squared total."""
    total = spans_length(spans)
    if total <= 0:
        return 0.0
    extra = sum(s.length ** 2 for s in spans
                if s.attn == ATTN_BIDIRECTIONAL)
    return extra / float(total) ** 2


def validate_spans(spans: Seq[ModalitySpan]) -> Tuple[ModalitySpan, ...]:
    """Sort + check the spans tile [0, total) contiguously."""
    out = tuple(sorted(spans, key=lambda s: s.start))
    off = 0
    for s in out:
        if s.start != off:
            raise ValueError(
                f"spans must tile the sequence contiguously from 0: "
                f"expected start {off}, got {s}")
        off = s.end
    return out


def slice_spans(spans: Seq[ModalitySpan], start: int,
                length: int) -> Tuple[ModalitySpan, ...]:
    """Clip a span layout to the window [start, start+length), re-based
    to 0 — how chunked prefill describes one chunk's structure."""
    end = start + length
    out = []
    for sp in sorted(spans, key=lambda s: s.start):
        a, b = max(sp.start, start), min(sp.end, end)
        if b > a:
            out.append(ModalitySpan(sp.modality, a - start, b - a,
                                    sp.attn))
    return tuple(out)


def synthesize_spans(length: int, eta: float, *,
                     modality: str = "vision") -> Tuple[ModalitySpan, ...]:
    """Span layout whose DERIVED eta realises a target scalar eta: one
    bidirectional prefix of v = round(sqrt(eta)*length) tokens plus a
    causal text remainder, achieving eta' = v^2/length^2. Exact (bit
    identical through `spans_eta`) whenever sqrt(eta)*length is
    integral; otherwise the nearest representable layout."""
    eta = min(max(float(eta), 0.0), 1.0)
    v = min(int(round(math.sqrt(eta) * length)), length)
    spans = []
    if v > 0:
        spans.append(ModalitySpan(modality, 0, v, ATTN_BIDIRECTIONAL))
    if length - v > 0:
        spans.append(ModalitySpan("text", v, length - v, ATTN_CAUSAL))
    return tuple(spans)


@dataclasses.dataclass(frozen=True)
class SeqInfo:
    """One training sequence (text + vision tokens, already concatenated).

    `spans` (optional) is the structural description; when present,
    `length` and `eta` are RE-DERIVED from it at construction, so a
    span-bearing SeqInfo can never disagree with its own geometry.
    Plain `SeqInfo(length, eta)` remains the scalar fallback."""

    length: int              # total token count |s_k|
    eta: float = 0.0         # mask efficiency factor (Eq. 8)
    seq_id: int = -1         # stable id for assignment matrices
    spans: Optional[Tuple[ModalitySpan, ...]] = None

    def __post_init__(self):
        if self.spans:
            spans = validate_spans(self.spans)
            object.__setattr__(self, "spans", spans)
            object.__setattr__(self, "length", spans_length(spans))
            object.__setattr__(self, "eta", spans_eta(spans))

    @property
    def attn_weight(self) -> float:
        """(1 + eta) |s|^2 — the quadratic attention term."""
        return (1.0 + self.eta) * float(self.length) ** 2

    @property
    def linear_weight(self) -> float:
        return float(self.length)


@dataclasses.dataclass(frozen=True)
class MMSequence:
    """A multimodal sequence as its span structure — the first-class
    planner input. Everything downstream (cost model, packer, PlanCache,
    kernels) consumes the `SeqInfo` view (`.seq_info`), which carries
    the spans along; `Strategy.plan` accepts MMSequences directly."""

    spans: Tuple[ModalitySpan, ...]
    seq_id: int = -1

    def __post_init__(self):
        object.__setattr__(self, "spans", validate_spans(self.spans))

    @property
    def length(self) -> int:
        return spans_length(self.spans)

    @property
    def eta(self) -> float:
        return spans_eta(self.spans)

    @property
    def seq_info(self) -> SeqInfo:
        """Backward-compatible scalar view (length/eta derived)."""
        return SeqInfo(length=0, eta=0.0, seq_id=self.seq_id,
                       spans=self.spans)

    # duck-type the SeqInfo surface so cost-model code accepts either
    @property
    def attn_weight(self) -> float:
        return (1.0 + self.eta) * float(self.length) ** 2

    @property
    def linear_weight(self) -> float:
        return float(self.length)

    def modality_tokens(self) -> dict:
        out: dict = {}
        for s in self.spans:
            out[s.modality] = out.get(s.modality, 0) + s.length
        return out


def as_seq_infos(seqs: Seq) -> list:
    """Normalize a batch that may mix MMSequence and SeqInfo."""
    return [s.seq_info if isinstance(s, MMSequence) else s for s in seqs]


@dataclasses.dataclass(frozen=True)
class CostCoeffs:
    """Profiled coefficients (seconds). See Profiler for how they are fit."""

    a1: float      # attention compute per (1+eta)|s|^2   [s / token^2]
    a2: float      # linear (MLP/QKV/...) compute per |s|  [s / token]
    b1: float      # per-microbatch fixed compute overhead [s]
    a3: float      # ring comm bytes->time per |s| at unit bandwidth [s*GBps/token]
    b2: float      # per-microbatch fixed comm overhead    [s]
    m_token: float # activation bytes per token (Eq. 7)    [bytes/token]
    m_ms: float    # model-state bytes per rank (ZeRO-3)   [bytes]


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Bandwidth topology used for v_p in Eq. 9 (GB/s per link)."""

    intra_bw: float = 50.0    # ICI link bandwidth inside a pod / node
    inter_bw: float = 6.0     # DCI bandwidth across pods / nodes
    ranks_per_node: int = 8   # ring spanning more than this uses inter_bw

    def ring_bandwidth(self, degree: int) -> float:
        """Bandwidth of the slowest link in a CP ring of `degree` ranks."""
        if degree <= 1:
            return float("inf")
        return self.intra_bw if degree <= self.ranks_per_node else self.inter_bw


class CostModel:
    """Evaluates Eqs. (7)-(10) for a set of sequences under CP degree d."""

    #: Bumped whenever the model's predictions may change (MeasuredCostModel
    #: increments it on every record()). Warm-started allocator states key
    #: on this so stale cost tables are never reused across model updates.
    cost_version: int = 0

    def __init__(self, coeffs: CostCoeffs, hw: Hardware | None = None):
        self.coeffs = coeffs
        self.hw = hw or Hardware()

    # ---- Eq. 7 -----------------------------------------------------------
    def memory(self, seqs: Seq[SeqInfo]) -> float:
        """Total activation+state bytes of a CP group (before / d split)."""
        c = self.coeffs
        return sum(s.length for s in seqs) * c.m_token + c.m_ms

    def min_degree(self, seqs: Seq[SeqInfo], budget: float) -> int:
        """d_min = ceil(M / (E * 1)) with per-rank budget E (Eq. 3)."""
        act = sum(s.length for s in seqs) * self.coeffs.m_token
        avail = budget - self.coeffs.m_ms
        if avail <= 0:
            raise ValueError(
                f"per-rank budget {budget:.3g} B cannot even hold model "
                f"states {self.coeffs.m_ms:.3g} B")
        import math
        return max(1, math.ceil(act / avail))

    # ---- Eq. 8 -----------------------------------------------------------
    def compute_time(self, seqs: Seq[SeqInfo], degree: int) -> float:
        c = self.coeffs
        attn = c.a1 * sum(s.attn_weight for s in seqs)
        lin = c.a2 * sum(s.linear_weight for s in seqs)
        return (attn + lin) / degree + c.b1

    def attn_compute_time(self, seqs: Seq[SeqInfo], degree: int) -> float:
        """T_cpa: only the attention part (the overlappable compute)."""
        return self.coeffs.a1 * sum(s.attn_weight for s in seqs) / degree

    # ---- Eq. 9 -----------------------------------------------------------
    def comm_time(self, seqs: Seq[SeqInfo], degree: int) -> float:
        if degree <= 1:
            return 0.0
        c = self.coeffs
        v = self.hw.ring_bandwidth(degree)
        vol = c.a3 * sum(s.length for s in seqs) * (degree - 1) / degree
        return vol / v + c.b2

    def attn_comm_time(self, seqs: Seq[SeqInfo], degree: int) -> float:
        """T_cma: the KV-ring traffic (all of Eq. 9's variable part)."""
        if degree <= 1:
            return 0.0
        c = self.coeffs
        v = self.hw.ring_bandwidth(degree)
        return c.a3 * sum(s.length for s in seqs) * (degree - 1) / degree / v

    # ---- Eq. 10 ----------------------------------------------------------
    def group_time(self, seqs: Seq[SeqInfo], degree: int) -> float:
        """Estimated wall time of one CP group executing its sequences."""
        if not seqs:
            return 0.0
        t_cp = self.compute_time(seqs, degree)
        t_cm = self.comm_time(seqs, degree)
        t_cpa = self.attn_compute_time(seqs, degree)
        t_cma = self.attn_comm_time(seqs, degree)
        return t_cp + t_cm - min(t_cpa, t_cma)

    def group_time_vector(self, seqs: Seq[SeqInfo],
                          degrees: np.ndarray) -> np.ndarray:
        """Eq. 10 for ONE group at MANY CP degrees in a single call.

        Bit-identical to ``[self.group_time(seqs, d) for d in degrees]``:
        the per-group aggregates (sum of attn/linear weights, token count)
        are reduced once with the same Python summation order the scalar
        path uses, after which every remaining operation is an elementwise
        float64 op whose IEEE semantics match the scalar expression
        exactly. The vectorized allocator certifies this equivalence in
        tests/test_allocator.py.
        """
        d = np.asarray(degrees, dtype=np.float64)
        if not seqs:
            return np.zeros(d.shape)
        c = self.coeffs
        # Aggregates, summed in the scalar path's order.
        attn = c.a1 * sum(s.attn_weight for s in seqs)
        lin = c.a2 * sum(s.linear_weight for s in seqs)
        toks = c.a3 * sum(s.length for s in seqs)
        t_cp = (attn + lin) / d + c.b1
        t_cpa = attn / d
        ring = np.where(d <= self.hw.ranks_per_node,
                        self.hw.intra_bw, self.hw.inter_bw)
        vol = toks * (d - 1.0) / d              # 0 at d=1, so no div issues
        t_cm = np.where(d <= 1.0, 0.0, vol / ring + c.b2)
        t_cma = np.where(d <= 1.0, 0.0, toks * (d - 1.0) / d / ring)
        return t_cp + t_cm - np.minimum(t_cpa, t_cma)

    def time_fn(self) -> Callable[[Seq[SeqInfo], int], float]:
        return self.group_time


def analytic_coeffs(
    *,
    hidden: int,
    n_layers: int,
    n_heads: int,
    kv_heads: int,
    ffn: int,
    vocab: int,
    dtype_bytes: int = 2,
    peak_flops: float = 197e12,     # TPU v5e bf16
    mfu: float = 0.45,
    params: float | None = None,
    zero_shards: int = 64,
) -> CostCoeffs:
    """Roofline-derived coefficients for a transformer of the given shape.

    Used when no measured profile is available (the Profiler refines these
    by fitting measured samples, reproducing the paper's <8% error claim).
    Training step FLOPs ~ 3x forward (fwd + 2x bwd).
    """
    head_dim = hidden // n_heads
    # attention: QK^T + AV = 2 * 2 * L^2 * hidden FLOPs per layer (causal
    # halves it; eta interpolates back up -> fold the 1/2 into a1).
    attn_flops_per_tok2 = 3 * 2 * 2 * hidden * n_layers * 0.5
    # linear: qkv + o + mlp (+ lm head amortized)
    lin_flops_per_tok = 3 * 2 * (
        hidden * (hidden + 2 * kv_heads * head_dim)  # qkv
        + hidden * hidden                             # out proj
        + 3 * hidden * ffn                            # swiglu mlp
    ) * n_layers + 3 * 2 * hidden * vocab
    eff = peak_flops * mfu
    n_params = params if params is not None else (
        n_layers * (hidden * (hidden + 2 * kv_heads * head_dim)
                    + hidden * hidden + 3 * hidden * ffn)
        + vocab * hidden)
    # activation bytes/token: per layer ~ (attn intermediates + mlp) in bf16,
    # with activation checkpointing keeping ~4*hidden + ffn per layer resident.
    m_token = dtype_bytes * n_layers * (4 * hidden + ffn) * 0.25
    # ZeRO-3: params+grads+optimizer(fp32 m,v,master) / shards
    m_ms = n_params * (2 + 2 + 12) / zero_shards
    # ring comm: 2 (K and V) * kv_heads*head_dim * bytes per token per hop
    a3 = 2 * kv_heads * head_dim * dtype_bytes / 1e9  # GB per token-hop
    return CostCoeffs(
        a1=attn_flops_per_tok2 / eff,
        a2=lin_flops_per_tok / eff,
        b1=2e-3,
        a3=a3,
        b2=1e-4,
        m_token=m_token,
        m_ms=m_ms,
    )
