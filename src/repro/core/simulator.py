"""Cluster simulator — reproduces the paper's end-to-end tables.

This container has no 64-NPU cluster, so the speedup experiments
(Figs. 4/5/6, Table 4) are reproduced by *simulation under the shared
cost model*: DHP's dynamic plans and the static Megatron-LM /
DeepSpeed-style plans are evaluated with identical Eq. (7)-(10) costs, so
the comparison isolates exactly what the paper isolates — the scheduling
policy — while the absolute scale is calibrated to TPU-v5e (or, via a
fitted Profiler, to measured CPU steps).

Megatron-LM baseline: static ring-CP degree sized for the longest
sequence, any integer degree allowed, CP groups of fixed size.
DeepSpeed baseline:  static Ulysses-style SP, degree restricted to
powers of two (head divisibility, §4.1), all-to-all comm with the same
linear volume model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence as Seq

import numpy as np

from .cost_model import CostModel, SeqInfo
from .distributions import sample_batch
from .scheduler import DHPScheduler, ExecutionPlan, static_plan


@dataclasses.dataclass
class IterationResult:
    method: str
    iter_time_s: float
    tokens: int
    schedule_ms: float
    solver_ms: float
    degree_histogram: Dict[int, int]

    @property
    def throughput_tokens_per_s(self) -> float:
        return self.tokens / self.iter_time_s


class ClusterSimulator:
    """Evaluates scheduling policies on one global batch."""

    def __init__(self, cost_model: CostModel, n_ranks: int,
                 mem_budget: float):
        self.cm = cost_model
        self.n_ranks = n_ranks
        self.budget = mem_budget

    def _result(self, name: str, plan: ExecutionPlan,
                seqs: Seq[SeqInfo]) -> IterationResult:
        return IterationResult(
            method=name,
            iter_time_s=plan.total_time_est,
            tokens=sum(s.length for s in seqs),
            schedule_ms=plan.schedule_ms,
            solver_ms=plan.solver_ms,
            degree_histogram=plan.degree_histogram,
        )

    def run_dhp(self, seqs: Seq[SeqInfo]) -> IterationResult:
        sched = DHPScheduler(self.cm, self.n_ranks, self.budget)
        return self._result("dhp", sched.schedule(seqs), seqs)

    def run_dhp_faithful(self, seqs: Seq[SeqInfo]) -> IterationResult:
        """Paper-faithful DHP: BFD + 2D-DP only, no beyond-paper
        refinements (balance-aware packing, serial fallback)."""
        sched = DHPScheduler(self.cm, self.n_ranks, self.budget,
                             balance_packing=False, serial_fallback=False)
        return self._result("dhp-faithful", sched.schedule(seqs), seqs)

    def run_megatron(self, seqs: Seq[SeqInfo]) -> IterationResult:
        plan = static_plan(seqs, self.cm, self.n_ranks, self.budget,
                           power_of_two=False)
        return self._result("megatron-lm", plan, seqs)

    def run_deepspeed(self, seqs: Seq[SeqInfo]) -> IterationResult:
        plan = static_plan(seqs, self.cm, self.n_ranks, self.budget,
                           power_of_two=True)
        return self._result("deepspeed", plan, seqs)

    def compare(self, seqs: Seq[SeqInfo]) -> Dict[str, IterationResult]:
        return {
            "dhp": self.run_dhp(seqs),
            "dhp-faithful": self.run_dhp_faithful(seqs),
            "megatron-lm": self.run_megatron(seqs),
            "deepspeed": self.run_deepspeed(seqs),
        }


def end_to_end_table(
    cost_model: CostModel,
    *,
    n_ranks: int = 64,
    mem_budget: float,
    datasets: Seq[str] = ("msrvtt", "internvid", "openvid"),
    gbs: int = 512,
    iters: int = 5,
    seed: int = 0,
    max_tokens: int | None = None,
) -> List[dict]:
    """Fig. 4/6 reproduction: iteration time + speedup per dataset."""
    rng = np.random.default_rng(seed)
    sim = ClusterSimulator(cost_model, n_ranks, mem_budget)
    rows = []
    for ds in datasets:
        acc = {m: 0.0 for m in ("dhp", "dhp-faithful", "megatron-lm",
                                "deepspeed")}
        for _ in range(iters):
            seqs = sample_batch(ds, gbs, rng, max_tokens=max_tokens)
            res = sim.compare(seqs)
            for m, r in res.items():
                acc[m] += r.iter_time_s
        best_static = min(acc["megatron-lm"], acc["deepspeed"])
        rows.append({
            "dataset": ds,
            "dhp_s": acc["dhp"] / iters,
            "dhp_faithful_s": acc["dhp-faithful"] / iters,
            "megatron_s": acc["megatron-lm"] / iters,
            "deepspeed_s": acc["deepspeed"] / iters,
            "speedup_vs_best_static": best_static / acc["dhp"],
            "speedup_faithful_vs_best_static": best_static
            / acc["dhp-faithful"],
            "speedup_vs_megatron": acc["megatron-lm"] / acc["dhp"],
        })
    return rows


def scaling_table(
    cost_model: CostModel,
    *,
    rank_counts: Seq[int] = (8, 16, 32, 64),
    mem_budget: float,
    dataset: str = "openvid",
    gbs: int = 512,
    iters: int = 3,
    seed: int = 0,
    max_tokens: int | None = None,
) -> List[dict]:
    """Fig. 5 reproduction: throughput vs cluster size."""
    rng = np.random.default_rng(seed)
    rows = []
    for n in rank_counts:
        sim = ClusterSimulator(cost_model, n, mem_budget)
        acc = {m: [0.0, 0] for m in ("dhp", "dhp-faithful",
                                     "megatron-lm", "deepspeed")}
        for _ in range(iters):
            seqs = sample_batch(dataset, gbs, rng, max_tokens=max_tokens)
            for m, r in sim.compare(seqs).items():
                acc[m][0] += r.iter_time_s
                acc[m][1] += r.tokens
        row = {"ranks": n}
        for m, (t, tok) in acc.items():
            row[f"{m}_tokens_per_s_per_rank"] = tok / t / n
        row["dhp_vs_deepspeed"] = (
            row["dhp_tokens_per_s_per_rank"]
            / row["deepspeed_tokens_per_s_per_rank"])
        rows.append(row)
    return rows
