"""Block/paged KV-cache management for the serving runtime.

The physical decode cache is a fixed pytree of `n_slots` per-request
cache rows (so the vmapped decode step compiles once per bucketed
(n_slots, cache_len) shape — batch composition changes never re-jit).
On top of that sits *paged accounting* in the vLLM style: KV capacity is
divided into fixed-size token blocks handed out by a free-list
allocator, every admitted request holds a block table, and admission
control is driven by block availability — so memory pressure behaves
like a real paged server even though the demo's physical layout is
slot-dense.

Invariants the tests pin down:
  * a block is owned by at most one request (`alloc` hands out each id
    once until it is freed);
  * `free` of a block not currently owned raises (double-free guard);
  * used + free == total at all times (no leaks);
  * releasing a request returns its slot AND all its blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class OutOfBlocks(RuntimeError):
    """KV pool exhausted — the scheduler must defer admission."""


class KVCacheError(RuntimeError):
    """Allocator misuse: double-free, unknown request, foreign block."""


@dataclasses.dataclass
class BlockTable:
    """Per-request logical->physical mapping: block i holds tokens
    [i*block_size, (i+1)*block_size) of the request's context."""

    request_id: int
    block_size: int
    block_ids: List[int] = dataclasses.field(default_factory=list)
    n_tokens: int = 0

    @property
    def capacity(self) -> int:
        return len(self.block_ids) * self.block_size


class BlockAllocator:
    """Free-list allocator over `n_blocks` KV blocks."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._owner: Dict[int, int] = {}      # block id -> request id

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return len(self._owner)

    def alloc(self, n: int, request_id: int) -> List[int]:
        """Pop `n` blocks for `request_id`; all-or-nothing."""
        if n > len(self._free):
            raise OutOfBlocks(
                f"need {n} blocks, {len(self._free)} free "
                f"(of {self.n_blocks})")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._owner[b] = request_id
        return blocks

    def free(self, blocks: List[int], request_id: int) -> None:
        # validate the whole batch BEFORE mutating, so a double-free /
        # foreign-free raises with the allocator unchanged instead of
        # half the blocks already returned to the free list
        for b in blocks:
            owner = self._owner.get(b)
            if owner is None:
                raise KVCacheError(f"double free of block {b}")
            if owner != request_id:
                raise KVCacheError(
                    f"block {b} owned by request {owner}, freed by "
                    f"{request_id}")
        for b in blocks:
            del self._owner[b]
            self._free.append(b)

    def check_conservation(self) -> None:
        assert self.n_free + self.n_used == self.n_blocks, (
            self.n_free, self.n_used, self.n_blocks)


@dataclasses.dataclass
class KVStats:
    admitted: int = 0
    released: int = 0
    peak_blocks: int = 0
    peak_slots: int = 0


class KVCacheManager:
    """Decode slots + paged block accounting for one serving engine.

    `admit(request_id, n_tokens)` reserves a decode slot and enough
    blocks for the request's full context (prompt + max generated) up
    front — eager reservation means an admitted request can never be
    preempted mid-decode by memory pressure, which keeps the runtime
    loop simple (the trade-off vs vLLM-style incremental allocation is
    noted in docs/api.md). `release` recycles both.
    """

    def __init__(self, n_slots: int, n_blocks: int, block_size: int = 16):
        self.n_slots = n_slots
        self.block_size = block_size
        self.allocator = BlockAllocator(n_blocks)
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._tables: Dict[int, BlockTable] = {}
        self._slot_of: Dict[int, int] = {}
        self.stats = KVStats()

    # -- queries ---------------------------------------------------------
    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.block_size))

    def can_admit(self, n_tokens: int) -> bool:
        return (bool(self._free_slots)
                and self.blocks_for(n_tokens) <= self.allocator.n_free)

    @property
    def occupancy(self) -> float:
        """Fraction of KV blocks currently owned by live requests."""
        return self.allocator.n_used / max(self.allocator.n_blocks, 1)

    def table(self, request_id: int) -> BlockTable:
        return self._tables[request_id]

    def slot(self, request_id: int) -> int:
        return self._slot_of[request_id]

    # -- lifecycle -------------------------------------------------------
    def admit(self, request_id: int, n_tokens: int) -> int:
        """Reserve a slot + blocks for `n_tokens` of context; returns the
        slot index. Raises OutOfBlocks / KVCacheError when infeasible."""
        if request_id in self._tables:
            raise KVCacheError(f"request {request_id} already admitted")
        if not self._free_slots:
            raise OutOfBlocks("no free decode slot")
        n = self.blocks_for(n_tokens)
        blocks = self.allocator.alloc(n, request_id)   # may raise
        slot = self._free_slots.pop()
        self._tables[request_id] = BlockTable(
            request_id=request_id, block_size=self.block_size,
            block_ids=blocks, n_tokens=n_tokens)
        self._slot_of[request_id] = slot
        self.stats.admitted += 1
        self.stats.peak_blocks = max(self.stats.peak_blocks,
                                     self.allocator.n_used)
        self.stats.peak_slots = max(self.stats.peak_slots,
                                    self.n_slots - self.n_free_slots)
        return slot

    def release(self, request_id: int) -> int:
        """Recycle the request's slot and blocks; returns the slot."""
        tab = self._tables.pop(request_id, None)
        if tab is None:
            raise KVCacheError(f"release of unknown request {request_id}")
        self.allocator.free(tab.block_ids, request_id)
        slot = self._slot_of.pop(request_id)
        self._free_slots.append(slot)
        self.stats.released += 1
        return slot
