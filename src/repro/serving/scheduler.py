"""Continuous-batching scheduler — the serving analogue of DHPScheduler.

Heterogeneous prompt lengths at inference are the same data-variability
problem DHP solves for training, so the serving scheduler reuses the
training planner stack wholesale: pending prefill work (one chunk per
request per iteration) is described as `SeqInfo`s and handed to a bound
`Strategy` (DHP by default), whose `ExecutionPlan` — `validate()`-checked
and `PlanCache`-cached — groups same-bucket prompts into co-executed
prefill batches and assigns each group a CP degree from the cost model,
exactly as the training path does for ragged global batches.

The scheduler itself is pure host-side Python (no jax): an
iteration-level loop that

  1. joins finished requests (slots + KV blocks recycled),
  2. admits queued requests while decode slots and KV blocks last,
  3. plans this iteration's prefill chunks with the DHP planner,
  4. names the decode set (every slot whose prefill is complete).

The runtime (serving/runtime.py) executes what `step()` returns.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np

from ..core.cost_model import SeqInfo, slice_spans
from ..core.scheduler import ExecutionPlan
from .kv_cache import KVCacheManager

# request lifecycle states
QUEUED, PREFILL, DECODE, FINISHED = "queued", "prefill", "decode", "finished"


@dataclasses.dataclass
class ServeRequest:
    """One inference request with arrival/deadline metadata."""

    request_id: int
    tokens: np.ndarray                  # prompt token ids [L] int32
    max_new_tokens: int = 32
    arrival_s: float = 0.0              # offset from trace start
    deadline_s: Optional[float] = None  # completion deadline (offset)
    eos_id: Optional[int] = None        # early-stop token id
    eta: float = 0.0                    # mask-efficiency factor (Eq. 8)
    #: modality layout of the prompt (ModalitySpan tuple; None = pure
    #: causal text). Span-bearing requests are prefetched through the
    #: span-aware chunked-prefill path so bidirectional vision/audio
    #: blocks are masked correctly, and the planner sees per-chunk
    #: derived eta instead of one scalar per request.
    spans: Optional[tuple] = None
    #: audio family only: encoder frames [F, d_model] (synthesized from
    #: the engine seed when None — mirroring Engine.serve)
    frames: Optional[np.ndarray] = None

    @property
    def prompt_len(self) -> int:
        return int(len(self.tokens))

    @property
    def context_len(self) -> int:
        """KV capacity the request may touch: prompt + generation."""
        return self.prompt_len + self.max_new_tokens


@dataclasses.dataclass
class RequestState:
    """Scheduler-side lifecycle record of one request."""

    request: ServeRequest
    status: str = QUEUED
    slot: int = -1
    #: prompt tokens whose KV is already in cache. Prefill covers
    #: prompt[:L-1]; prompt[L-1] is the first decode input (it produces
    #: the first generated token), so prefill is done at L-1.
    prefill_pos: int = 0
    generated: List[int] = dataclasses.field(default_factory=list)
    # timing (runtime fills these; offsets from trace start)
    enqueued_s: float = 0.0
    admitted_s: float = 0.0
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None

    @property
    def prefill_target(self) -> int:
        return max(self.request.prompt_len - 1, 0)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.prefill_target

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.enqueued_s


@dataclasses.dataclass
class PrefillChunk:
    """One request's prefill work this iteration."""

    request_id: int
    start: int          # first prompt position of the chunk
    length: int         # chunk token count (== SeqInfo.length planned)


@dataclasses.dataclass
class PrefillGroup:
    """Co-executed prefill chunks (one GroupPlan of the plan): the
    runtime pads them to one bucket and runs them as a batch. `degree`
    is the planner-assigned CP degree for the group."""

    chunks: List[PrefillChunk]
    degree: int


@dataclasses.dataclass
class IterationSchedule:
    """What the runtime executes for one loop iteration."""

    admitted: List[int]
    prefill_groups: List[PrefillGroup]
    decode_ids: List[int]               # request ids in decode this iter
    plan: Optional[ExecutionPlan]       # validated chunked-prefill plan
    queue_depth: int
    kv_occupancy: float


class ContinuousBatchingScheduler:
    """Iteration-level admission + planning over a KVCacheManager.

    `planner` is any bound `repro.api.Strategy` (its PlanCache makes
    recurring chunk-length histograms skip the 2D-DP solver — the
    serving reuse of the training plan cache). `prefill_chunk` bounds
    per-request prefill work per iteration so long prompts are chunked
    and decode iterations interleave between chunks instead of stalling
    behind a monolithic prefill.
    """

    def __init__(self, kv: KVCacheManager, planner, *,
                 prefill_chunk: int = 256,
                 max_prefill_seqs: Optional[int] = None,
                 prefill_needed: bool = True):
        """`prefill_needed=False` for state-cache families (ssm/hybrid/
        audio): the repo's serving convention (Engine.serve) starts them
        from a fresh state with the last prompt token as first decode
        input, so admission jumps straight to DECODE."""
        self.kv = kv
        self.planner = planner
        self.prefill_chunk = prefill_chunk
        self.max_prefill_seqs = max_prefill_seqs or kv.n_slots
        self.prefill_needed = prefill_needed
        self.queue: Deque[int] = deque()
        self.states: Dict[int, RequestState] = {}
        self.plans_validated = 0
        self.schedule_ms_total = 0.0

    # -- intake ----------------------------------------------------------
    def submit(self, request: ServeRequest, now: float = 0.0) -> None:
        if request.request_id in self.states:
            raise ValueError(
                f"duplicate request_id {request.request_id}")
        need = self.kv.blocks_for(request.context_len)
        if need > self.kv.allocator.n_blocks:
            # fail loudly NOW: this request can never be admitted, and
            # FIFO admission would otherwise head-of-line-block the
            # queue until the runtime's iteration cap trips
            raise ValueError(
                f"request {request.request_id} needs {need} KV blocks "
                f"for its {request.context_len}-token context; the "
                f"pool only has {self.kv.allocator.n_blocks}")
        st = RequestState(request=request, enqueued_s=now)
        self.states[request.request_id] = st
        self.queue.append(request.request_id)

    def has_work(self) -> bool:
        return bool(self.queue) or any(
            s.status in (PREFILL, DECODE) for s in self.states.values())

    @property
    def active(self) -> List[RequestState]:
        return [s for s in self.states.values()
                if s.status in (PREFILL, DECODE)]

    # -- lifecycle transitions driven by the runtime ---------------------
    def mark_prefilled(self, request_id: int, n_tokens: int) -> None:
        """Advance a request's prefill cursor by `n_tokens`."""
        st = self.states[request_id]
        st.prefill_pos = min(st.prefill_pos + n_tokens,
                             st.prefill_target)
        if st.prefill_done:
            st.status = DECODE

    def finish(self, request_id: int, now: float) -> None:
        """Join a finished request: recycle its slot + KV blocks."""
        st = self.states[request_id]
        assert st.status in (PREFILL, DECODE), st.status
        self.kv.release(request_id)
        st.status = FINISHED
        st.slot = -1
        st.finished_s = now

    # -- one scheduling iteration ---------------------------------------
    def step(self, now: float = 0.0) -> IterationSchedule:
        import time

        admitted = self._admit(now)
        t0 = time.perf_counter()
        groups, plan = self._plan_prefills()
        self.schedule_ms_total += (time.perf_counter() - t0) * 1e3
        decode_ids = sorted(
            rid for rid, s in self.states.items() if s.status == DECODE)
        return IterationSchedule(
            admitted=admitted,
            prefill_groups=groups,
            decode_ids=decode_ids,
            plan=plan,
            queue_depth=len(self.queue),
            kv_occupancy=self.kv.occupancy,
        )

    # -- admission -------------------------------------------------------
    def _admit(self, now: float) -> List[int]:
        """FIFO admission while a slot + blocks for the full context are
        available. Head-of-line blocking is intentional: admitting a
        short request past a starved long one would let long prompts
        starve forever under sustained load."""
        admitted: List[int] = []
        while self.queue:
            rid = self.queue[0]
            st = self.states[rid]
            if not self.kv.can_admit(st.request.context_len):
                break
            self.queue.popleft()
            st.slot = self.kv.admit(rid, st.request.context_len)
            if not self.prefill_needed:
                st.prefill_pos = st.prefill_target
            st.status = PREFILL if (self.prefill_needed
                                    and st.prefill_target > 0) else DECODE
            st.admitted_s = now
            admitted.append(rid)
        return admitted

    # -- prefill planning ------------------------------------------------
    def _chunk_len(self, st: RequestState) -> int:
        """Next chunk length for one request: at most `prefill_chunk`,
        but snapped FORWARD to the end of any bidirectional modality
        span the boundary would split — the chunk-level invariant that
        makes span-aware chunked prefill exact (a vision block's K/V
        must all be resident before any of its queries run)."""
        remaining = st.prefill_target - st.prefill_pos
        end = st.prefill_pos + min(self.prefill_chunk, remaining)
        for sp in st.request.spans or ():
            if (sp.attn == "bidirectional"
                    and sp.start < end < sp.start + sp.length):
                end = min(sp.start + sp.length, st.prefill_target)
                break
        return end - st.prefill_pos

    def _next_chunks(self) -> List[PrefillChunk]:
        chunks = []
        for rid, st in sorted(self.states.items()):
            if st.status != PREFILL:
                continue
            chunks.append(PrefillChunk(
                request_id=rid, start=st.prefill_pos,
                length=self._chunk_len(st)))
            if len(chunks) >= self.max_prefill_seqs:
                break
        return chunks

    def _plan_prefills(self):
        """Group this iteration's prefill chunks with the DHP planner.

        SeqInfo.seq_id carries the request id, SeqInfo.length the chunk
        length, so the plan's groups read directly as co-batched prefill
        sets; the plan is validated (coverage + Eq. 3/6) before the
        runtime may execute it."""
        chunks = self._next_chunks()
        if not chunks:
            return [], None
        by_id = {c.request_id: c for c in chunks}

        def chunk_info(c: PrefillChunk) -> SeqInfo:
            req = self.states[c.request_id].request
            if req.spans:
                # span-bearing request: the chunk's OWN layout drives
                # the derived eta the planner costs, not the request's
                # whole-prompt scalar
                return SeqInfo(length=0, seq_id=c.request_id,
                               spans=slice_spans(req.spans, c.start,
                                                 c.length))
            return SeqInfo(length=c.length, eta=req.eta,
                           seq_id=c.request_id)

        seqs = [chunk_info(c) for c in chunks]
        plan = self.planner.plan(seqs)
        plan.validate(seqs, n_ranks=self.planner.n_ranks,
                      cost_model=self.planner.cm,
                      mem_budget=self.planner.budget)
        self.plans_validated += 1
        groups = [
            PrefillGroup(chunks=[by_id[i] for i in g.seq_ids],
                         degree=g.degree)
            for mb in plan.micro_batches for g in mb.groups
        ]
        return groups, plan

    # -- reporting -------------------------------------------------------
    def finished_states(self) -> List[RequestState]:
        return [s for s in self.states.values() if s.status == FINISHED]
