"""ServingEngine — the continuous-batching serving runtime.

One event loop joins the three subsystems:

  ContinuousBatchingScheduler  (admission + DHP-planned chunked prefill)
  KVCacheManager               (decode slots + paged block accounting)
  slot-vmapped decode step     (serve_step.make_slot_decode_step)

Per iteration: admit arrivals, execute the planner's prefill groups
(bounded chunks, so decode never stalls behind a long prompt), then run
ONE decode step for every live slot. All executables live in the
cluster's shared GroupPool keyed on bucketed shapes — steady-state
serving compiles nothing, whatever the trace's request mix.

Request streams are greedy and deterministic: a request decoded here
yields exactly the token ids `greedy_generate` produces for the same
prompt (the parity invariant tests/test_serving.py pins per family).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence as Seq

import numpy as np

from ..configs.base import ModelConfig
from ..obs.metrics import MetricsRegistry
from ..obs.trace import Tracer, get_tracer, tracing
from .kv_cache import KVCacheManager
from .scheduler import (DECODE, ContinuousBatchingScheduler, PrefillGroup,
                        ServeRequest)

ATTN_FAMILIES = ("dense", "moe", "vlm")


@dataclasses.dataclass
class RequestMetrics:
    request_id: int
    prompt_len: int
    n_generated: int
    tokens: List[int]                # the greedy-decoded output ids
    ttft_s: Optional[float]          # first token - arrival
    mean_tpot_s: float               # mean time per output token
    queue_s: float                   # arrival -> admission
    deadline_met: Optional[bool]     # None when no deadline was set


@dataclasses.dataclass
class ServeReport:
    """Aggregate + per-request serving telemetry for one trace."""

    requests: List[RequestMetrics]
    wall_s: float
    total_tokens: int
    tokens_per_s: float
    mean_ttft_s: float
    max_ttft_s: float
    n_iterations: int
    n_decode_steps: int
    n_prefill_chunks: int
    schedule_ms: float               # host planning latency, summed
    plan_cache: Dict[str, int]
    exe_misses: int                  # executables compiled during the run
    queue_depth: List[int]           # sampled per iteration
    kv_occupancy: List[float]        # sampled per iteration
    peak_kv_blocks: int
    n_slots: int
    cache_len: int

    def summary(self) -> str:
        return (f"{len(self.requests)} requests, "
                f"{self.total_tokens} tokens in {self.wall_s:.2f}s "
                f"({self.tokens_per_s:.1f} tok/s) "
                f"ttft mean={self.mean_ttft_s * 1e3:.0f}ms "
                f"max={self.max_ttft_s * 1e3:.0f}ms "
                f"iters={self.n_iterations} "
                f"(decode={self.n_decode_steps} "
                f"prefill_chunks={self.n_prefill_chunks}) "
                f"compiled={self.exe_misses}")


class ServingEngine:
    """Continuous-batching runtime over one model + cluster.

    Build via `Engine.serving(...)`. The decode slot count and cache
    capacity are bucketed through the cluster ladder
    (`ClusterSpec.decode_shape`), so traces of different sizes reuse the
    same compiled decode step.
    """

    def __init__(self, cfg: ModelConfig, params, cluster, cost_model, *,
                 slots: int = 4, cache_len: Optional[int] = None,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 prefill_chunk: int = 128, strategy: str = "dhp",
                 seed: int = 0):
        from ..api.strategies import get_strategy
        self.cfg = cfg
        self.params = params
        self.cluster = cluster
        self.pool = cluster.pool()
        self.block_size = block_size
        # MoE capacity-factor routing is global over the routed token
        # set (padding or chunking a prompt changes expert assignment)
        # and sliding-window caches rotate on prefill: both families
        # prefill monolithically at exact length, first token taken
        # from the prefill logits (see _run_prefill_group).
        self.exact_prefill = (cfg.family == "moe"
                              or cfg.sliding_window is not None)
        self.prefill_chunk = (10 ** 9 if self.exact_prefill
                              else prefill_chunk)
        self.seed = seed
        self._cache_len = cache_len
        self._n_blocks = n_blocks
        self.n_slots, _ = cluster.decode_shape(slots, 1)
        self.attention_family = (cfg.family in ATTN_FAMILIES)
        # own planner instance: serving plans must not evict training
        # plans from an engine's strategy cache (PlanCache salt keeps
        # the key spaces disjoint even when a cache IS shared).
        self.planner = get_strategy(strategy).bind(
            cost_model, cluster.n_replicas, cluster.mem_budget)
        cache = self.planner.plan_cache
        if cache is not None:
            cache.salt = "serve-prefill"
        #: run-over-run counters/gauges/histograms (queue depth, KV
        #: occupancy, decode/prefill volume) — see docs/api.md
        #: "Observability". Folded in by _report at the end of run().
        self.metrics = MetricsRegistry()

    # -- pooled executables ---------------------------------------------
    def _exe(self, key, build):
        exe, _ = self.pool.executable_for(key, build)
        return exe

    def _decode_step(self, n_slots: int, T: int):
        import jax
        from .serve_step import make_slot_decode_step
        return self._exe(
            ("pserve", self.cfg.arch_id, self.cfg.family, n_slots, T),
            lambda: jax.jit(make_slot_decode_step(self.cfg)))

    def _writer(self, n_slots: int, T: int):
        import jax
        from .serve_step import write_slot
        return self._exe(
            ("slot_write", self.cfg.arch_id, self.cfg.family,
             n_slots, T),
            lambda: jax.jit(write_slot))

    def _group_prefill(self, rows: int, Sb: int, T: int):
        import jax
        from ..models.model import prefill
        cfg = self.cfg

        def fn(params, toks):
            return prefill(params, cfg, {"tokens": toks}, cache_len=T)
        return self._exe(
            ("gprefill", cfg.arch_id, rows, Sb, T),
            lambda: jax.jit(fn))

    def _chunk_prefill(self, Cb: int, T: int, with_spans: bool = False):
        import jax
        from ..models.model import prefill_chunk
        cfg = self.cfg

        if with_spans:
            def fn(params, cache, toks, start, span_ids, cache_span_ids):
                return prefill_chunk(params, cfg, cache, toks, start,
                                     span_ids=span_ids,
                                     cache_span_ids=cache_span_ids)
            key = ("cprefill", cfg.arch_id, Cb, T, "spans")
        else:
            def fn(params, cache, toks, start):
                return prefill_chunk(params, cfg, cache, toks, start)
            key = ("cprefill", cfg.arch_id, Cb, T)
        return self._exe(key, lambda: jax.jit(fn))

    def _span_row(self, request: ServeRequest, T: int) -> np.ndarray:
        """[1,T] cache-row modality table for one request: absolute
        positions of its bidirectional blocks, -1 elsewhere (including
        the generation region — decode is causal)."""
        from ..core.packing import fill_modality_row
        row = np.full((1, T), -1, np.int32)
        fill_modality_row(row[0], request.spans, 0,
                          min(request.prompt_len, T), 0)
        return row

    # -- staging caches --------------------------------------------------
    def _fresh_cache(self, request: ServeRequest, T: int):
        """B=1 starting cache for one admitted request (audio gets its
        cross-KV prefilled here, mirroring Engine.serve)."""
        import jax
        import jax.numpy as jnp
        from ..models.model import init_cache, prefill_cross_kv
        cache = init_cache(self.cfg, 1, T)
        if self.cfg.family == "audio":
            if request.frames is not None:
                frames = jnp.asarray(request.frames)[None]
            else:
                frames = jax.random.normal(
                    jax.random.PRNGKey(self.seed + 2),
                    (1, self.cfg.encdec.n_audio_frames,
                     self.cfg.d_model))
            cache = prefill_cross_kv(self.params, self.cfg, frames,
                                     cache)
        return cache

    # -- prefill execution -----------------------------------------------
    def _run_prefill_group(self, group: PrefillGroup, sched, staging,
                           pending_first, T: int) -> int:
        """Execute one planner group; returns chunk count executed."""
        import jax.numpy as jnp
        tr = get_tracer()
        one_shot, chunked = [], []
        for c in group.chunks:
            st = sched.states[c.request_id]
            if (c.start == 0 and c.length == st.prefill_target
                    and not self.exact_prefill
                    and st.request.spans is None):
                # span-bearing prompts always take the chunked path so
                # their bidirectional blocks are masked (the co-batched
                # one-shot prefill is causal-only)
                one_shot.append(c)
            else:
                chunked.append(c)

        if one_shot:
            # co-batched full-prompt prefill, padded to one bucket. Rows
            # are right-padded: causal attention makes KV[0:L-1] of a
            # padded row identical to the exact-length computation, and
            # decode re-derives position L-1 itself, so padding never
            # leaks into a request's stream.
            Sb = self.pool.bucket(max(c.length for c in one_shot))
            from ..core.group_pool import pow2_bucket
            rows = pow2_bucket(len(one_shot), minimum=1)
            toks = np.zeros((rows, Sb), np.int32)
            for r, c in enumerate(one_shot):
                toks[r, :c.length] = \
                    sched.states[c.request_id].request.tokens[:c.length]
            with tr.span("prefill_batch", "serve",
                         args={"rows": rows, "bucket": Sb,
                               "prompts": len(one_shot)}):
                _, cache = self._group_prefill(rows, Sb, T)(
                    self.params, jnp.asarray(toks))
            for r, c in enumerate(one_shot):
                row = {
                    "k": cache["k"][:, r:r + 1],
                    "v": cache["v"][:, r:r + 1],
                    "pos": jnp.asarray(c.length, jnp.int32),
                }
                staging[c.request_id] = {**staging[c.request_id], **row}
                sched.mark_prefilled(c.request_id, c.length)

        for c in chunked:
            st = sched.states[c.request_id]
            if self.exact_prefill:
                # ring caches rotate on prefill and MoE routing is
                # padding/chunking-sensitive: run the WHOLE prompt
                # exact-length (compiled per distinct length) against
                # the capacity the slot cache actually holds, and take
                # the first generated token straight from the prefill
                # logits — the reference path, token for token.
                assert c.start == 0 and c.length == st.prefill_target
                Tring = (min(self.cfg.sliding_window, T)
                         if self.cfg.sliding_window is not None else T)
                L = st.request.prompt_len
                toks = st.request.tokens[None, :]
                with tr.span("prefill_exact", "serve",
                             args={"request": c.request_id,
                                   "length": L}):
                    logits, cache = self._group_prefill(1, L, Tring)(
                        self.params, jnp.asarray(toks))
                pending_first[c.request_id] = int(
                    np.argmax(np.asarray(logits)[0, 0]))
                staging[c.request_id] = {
                    **staging[c.request_id], "k": cache["k"],
                    "v": cache["v"],
                    "pos": jnp.asarray(L, jnp.int32)}
                sched.mark_prefilled(c.request_id, c.length)
                continue
            from ..core.group_pool import pow2_bucket
            Cb = pow2_bucket(c.length, minimum=16)
            toks = np.zeros((1, Cb), np.int32)
            toks[0, :c.length] = \
                st.request.tokens[c.start:c.start + c.length]
            with tr.span("prefill_chunk", "serve",
                         args={"request": c.request_id,
                               "start": c.start, "length": c.length,
                               "bucket": Cb}):
                if st.request.spans is not None:
                    row = self._span_row(st.request, T)
                    cs = np.full((1, Cb), -1, np.int32)
                    cs[0, :c.length] = row[0, c.start:c.start + c.length]
                    cache = self._chunk_prefill(Cb, T, with_spans=True)(
                        self.params, staging[c.request_id],
                        jnp.asarray(toks), c.start, jnp.asarray(cs),
                        jnp.asarray(row))
                else:
                    cache = self._chunk_prefill(Cb, T)(
                        self.params, staging[c.request_id],
                        jnp.asarray(toks), c.start)
            # pos is owned by the bookkeeping here, not the padded chunk
            cache = {**cache,
                     "pos": jnp.asarray(c.start + c.length, jnp.int32)}
            staging[c.request_id] = cache
            sched.mark_prefilled(c.request_id, c.length)
        return len(group.chunks)

    # -- the loop ---------------------------------------------------------
    def run(self, requests: Seq[ServeRequest], *,
            log=None, trace=None) -> ServeReport:
        """Serve a trace to completion; returns the ServeReport.

        `trace`: a path, True, or a Tracer — records a Chrome
        trace-event timeline of the loop (prefill batches/chunks,
        decode steps, queue-depth and KV-occupancy counter tracks);
        saved to the path when one is given."""
        tracer: Optional[Tracer] = None
        trace_path: Optional[str] = None
        if trace is not None and trace is not False:
            if isinstance(trace, str):
                trace_path, tracer = trace, Tracer()
            elif trace is True:
                tracer = Tracer()
            else:
                tracer = trace
        if tracer is not None:
            try:
                with tracing(tracer):
                    report = self._run(requests, log=log)
            finally:
                if trace_path is not None:
                    tracer.save(trace_path)
            return report
        return self._run(requests, log=log)

    def _run(self, requests: Seq[ServeRequest], *,
             log=None) -> ServeReport:
        import jax.numpy as jnp
        from .serve_step import make_slot_cache
        tr = get_tracer()

        requests = sorted(requests, key=lambda r: (r.arrival_s,
                                                   r.request_id))
        if not requests:
            raise ValueError("empty trace")
        max_ctx = max(r.context_len for r in requests)
        _, T = self.cluster.decode_shape(self.n_slots, max_ctx)
        if self._cache_len is not None:
            T = max(T, self._cache_len)
        n_blocks = self._n_blocks or max(
            1, (self.n_slots * T) // self.block_size)
        kv = KVCacheManager(self.n_slots, n_blocks, self.block_size)
        sched = ContinuousBatchingScheduler(
            kv, self.planner, prefill_chunk=self.prefill_chunk,
            prefill_needed=self.attention_family)

        exe_misses0 = self.pool.stats.exe_misses
        slots = make_slot_cache(self.cfg, self.n_slots, T)
        decode = self._decode_step(self.n_slots, T)
        writer = self._writer(self.n_slots, T)
        staging: Dict[int, Any] = {}
        pending_first: Dict[int, int] = {}
        next_token: Dict[int, int] = {}
        slot_of: Dict[int, int] = {}
        queue_depth: List[int] = []
        kv_occ: List[float] = []
        token_times: Dict[int, List[float]] = {}
        n_iters = n_decode = n_chunks = 0
        arrivals = list(requests)
        t0 = time.perf_counter()
        skip = 0.0                      # virtual fast-forward while idle

        def now() -> float:
            return time.perf_counter() - t0 + skip

        max_iters = 10 * sum(r.max_new_tokens for r in requests) + \
            10 * len(requests) + 100
        while arrivals or sched.has_work():
            n_iters += 1
            if n_iters > max_iters:
                raise RuntimeError(
                    f"serving loop did not converge in {max_iters} "
                    f"iterations")
            t = now()
            while arrivals and arrivals[0].arrival_s <= t:
                r = arrivals.pop(0)
                sched.submit(r, now=r.arrival_s)
            if not sched.has_work():
                skip += arrivals[0].arrival_s - t   # idle: fast-forward
                continue

            it = sched.step(t)
            queue_depth.append(it.queue_depth)
            kv_occ.append(it.kv_occupancy)
            if tr.enabled:
                tr.counter("queue_depth", {"waiting": it.queue_depth})
                tr.counter("kv_occupancy",
                           {"fraction": it.kv_occupancy})

            for rid in it.admitted:
                st = sched.states[rid]
                staging[rid] = self._fresh_cache(st.request, T)
                next_token[rid] = int(st.request.tokens[-1])
                token_times[rid] = []

            for group in it.prefill_groups:
                with tr.span("prefill_group", "serve",
                             args={"iter": n_iters,
                                   "chunks": len(group.chunks)}):
                    n_chunks += self._run_prefill_group(
                        group, sched, staging, pending_first, T)

            # prefill-complete requests move into their decode slot.
            # The staged cache carries the right pos per path: L-1 for
            # chunked/batched attention prefill (last prompt token is
            # the first decode input), L for exact-prefill families
            # (first token already taken from the prefill logits), 0
            # for fresh state caches — Engine.serve's conventions.
            for rid in list(sched.states):
                st = sched.states[rid]
                if not (st.status == DECODE and rid in staging):
                    continue
                slots = writer(slots, staging.pop(rid), st.slot)
                slot_of[rid] = st.slot
                if rid in pending_first:
                    tok = pending_first.pop(rid)
                    t_tok = now()
                    st.generated.append(tok)
                    next_token[rid] = tok
                    token_times[rid].append(t_tok)
                    st.first_token_s = t_tok
                    req = st.request
                    if (len(st.generated) >= req.max_new_tokens
                            or (req.eos_id is not None
                                and tok == req.eos_id)):
                        sched.finish(rid, t_tok)
                        del slot_of[rid]

            # decode set derived AFTER the insert pass, not from the
            # schedule: the vmapped step advances every slot, so a slot
            # whose request was inserted this iteration must decode this
            # iteration too — otherwise the step feeds it a pad token
            # and shifts the request's stream by one garbage write.
            decode_ids = sorted(
                rid for rid, s in sched.states.items()
                if s.status == DECODE and rid in slot_of)
            if decode_ids:
                toks = np.zeros((self.n_slots, 1), np.int32)
                for rid in decode_ids:
                    toks[slot_of[rid], 0] = next_token[rid]
                with tr.span("decode", "serve",
                             args={"iter": n_iters,
                                   "live": len(decode_ids)}):
                    out, slots = decode(self.params, slots,
                                        jnp.asarray(toks))
                    out = np.asarray(out)
                n_decode += 1
                t_tok = now()
                for rid in decode_ids:
                    st = sched.states[rid]
                    tok = int(out[slot_of[rid]])
                    st.generated.append(tok)
                    next_token[rid] = tok
                    token_times[rid].append(t_tok)
                    if st.first_token_s is None:
                        st.first_token_s = t_tok
                    req = st.request
                    if (len(st.generated) >= req.max_new_tokens
                            or (req.eos_id is not None
                                and tok == req.eos_id)):
                        sched.finish(rid, t_tok)
                        del slot_of[rid]
                        if log is not None:
                            log(f"request {rid} finished: "
                                f"{len(st.generated)} tokens, "
                                f"ttft={st.ttft_s * 1e3:.0f}ms")

        wall = time.perf_counter() - t0
        return self._report(sched, token_times, wall, T,
                            n_iters, n_decode, n_chunks,
                            queue_depth, kv_occ, kv,
                            self.pool.stats.exe_misses - exe_misses0)

    # -- reporting --------------------------------------------------------
    def _report(self, sched, token_times, wall, T, n_iters, n_decode,
                n_chunks, queue_depth, kv_occ, kv,
                exe_misses) -> ServeReport:
        reqs = []
        for st in sched.finished_states():
            times = token_times.get(st.request.request_id, [])
            gaps = np.diff(times) if len(times) > 1 else []
            r = st.request
            reqs.append(RequestMetrics(
                request_id=r.request_id,
                prompt_len=r.prompt_len,
                n_generated=len(st.generated),
                tokens=list(st.generated),
                ttft_s=st.ttft_s,
                mean_tpot_s=float(np.mean(gaps)) if len(gaps) else 0.0,
                queue_s=st.admitted_s - st.enqueued_s,
                deadline_met=(None if r.deadline_s is None
                              else st.finished_s <= r.deadline_s)))
        total = sum(m.n_generated for m in reqs)
        ttfts = [m.ttft_s for m in reqs if m.ttft_s is not None]
        cache = self.planner.plan_cache
        reg = self.metrics
        reg.counter("serve/requests").inc(len(reqs))
        reg.counter("serve/tokens").inc(total)
        reg.counter("serve/iterations").inc(n_iters)
        reg.counter("serve/decode_steps").inc(n_decode)
        reg.counter("serve/prefill_chunks").inc(n_chunks)
        reg.counter("serve/exe_misses").inc(exe_misses)
        for t in ttfts:
            reg.histogram("serve/ttft_s").observe(t)
        for q in queue_depth:
            reg.histogram("serve/queue_depth").observe(q)
        for occ in kv_occ:
            reg.histogram("serve/kv_occupancy").observe(occ)
        reg.gauge("serve/peak_kv_blocks").set(kv.stats.peak_blocks)
        if cache is not None:
            reg.update_from(dict(cache.stats), "plan/cache_")
        return ServeReport(
            requests=sorted(reqs, key=lambda m: m.request_id),
            wall_s=wall,
            total_tokens=total,
            tokens_per_s=total / max(wall, 1e-9),
            mean_ttft_s=float(np.mean(ttfts)) if ttfts else 0.0,
            max_ttft_s=float(np.max(ttfts)) if ttfts else 0.0,
            n_iterations=n_iters,
            n_decode_steps=n_decode,
            n_prefill_chunks=n_chunks,
            schedule_ms=sched.schedule_ms_total,
            plan_cache=dict(cache.stats) if cache is not None else {},
            exe_misses=exe_misses,
            queue_depth=queue_depth,
            kv_occupancy=kv_occ,
            peak_kv_blocks=kv.stats.peak_blocks,
            n_slots=self.n_slots,
            cache_len=T,
        )
