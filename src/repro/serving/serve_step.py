"""Serving: batched one-token decode steps (the decode_* input shapes).

`make_serve_step(cfg)` returns the jit-able step lowered by the dry-run:
one new token against a KV/state cache of `seq_len` capacity.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from ..models.model import decode_step, init_cache


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache: Dict[str, Any], tokens: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
        logits, cache = decode_step(params, cfg, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


def cache_for_shape(cfg: ModelConfig, shape: InputShape,
                    dtype=None) -> Dict[str, Any]:
    cache = init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    # decode starts with a full context
    return {**cache, "pos": jnp.asarray(shape.seq_len, jnp.int32)}


# --------------------------------------------------------------------------
# Slot-vmapped decode (the continuous-batching runtime's step)
# --------------------------------------------------------------------------
def make_slot_cache(cfg: ModelConfig, n_slots: int, cache_len: int,
                    dtype=None) -> Dict[str, Any]:
    """Physical store of `n_slots` independent B=1 decode caches: every
    leaf of `init_cache(cfg, 1, cache_len)` gains a leading slot axis.
    Each slot keeps its OWN `pos` scalar — the property that lets
    requests at different context depths share one decode step."""
    one = init_cache(cfg, 1, cache_len, dtype)
    return jax.tree.map(
        lambda x: jnp.zeros((n_slots,) + x.shape, x.dtype), one)


def make_slot_decode_step(cfg: ModelConfig):
    """One decode iteration over every slot at once.

    `jax.vmap` of the single-request decode over the slot axis: per-slot
    positions, ring writes and state updates all batch into one compiled
    executable whose shape depends only on (n_slots, cache_len) — decode
    batch composition (which request sits in which slot) can change
    every iteration without re-jitting. Returns (next_tokens [n_slots],
    slots) with greedy argmax applied, mirroring make_serve_step."""
    def one(params, cache, tok):
        logits, cache = decode_step(params, cfg, cache, tok)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def step(params, slots, tokens):
        # tokens: [n_slots, 1] (each slot is a B=1 cache)
        toks, slots = jax.vmap(one, in_axes=(None, 0, 0))(
            params, slots, tokens)
        return toks[:, 0], slots
    return step


def write_slot(slots, cache, idx):
    """Insert one B=1 request cache into slot `idx` (jit under the
    caller; `idx` is traced so one executable serves every slot)."""
    return jax.tree.map(
        lambda buf, c: jax.lax.dynamic_update_index_in_dim(
            buf, jnp.asarray(c, buf.dtype), idx, axis=0),
        slots, cache)


def greedy_generate(params, cfg: ModelConfig, cache, first_token,
                    n_tokens: int, step=None):
    """Host-loop generation used by examples/tests (not the dry-run).

    `step`: a prebuilt jitted serve step — pass one fetched from a
    GroupPool executable cache (as `Engine.serve` does) so repeated
    serve calls on the same (batch, cache) shape reuse the compiled
    artifact instead of re-jitting per call."""
    if step is None:
        step = jax.jit(make_serve_step(cfg))
    tok = first_token
    out = []
    for _ in range(n_tokens):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.stack(out, axis=1), cache
