"""Serving: batched one-token decode steps (the decode_* input shapes).

`make_serve_step(cfg)` returns the jit-able step lowered by the dry-run:
one new token against a KV/state cache of `seq_len` capacity.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from ..models.model import decode_step, init_cache


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache: Dict[str, Any], tokens: jax.Array
                   ) -> Tuple[jax.Array, Dict[str, Any]]:
        logits, cache = decode_step(params, cfg, cache, tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


def cache_for_shape(cfg: ModelConfig, shape: InputShape,
                    dtype=None) -> Dict[str, Any]:
    cache = init_cache(cfg, shape.global_batch, shape.seq_len, dtype)
    # decode starts with a full context
    return {**cache, "pos": jnp.asarray(shape.seq_len, jnp.int32)}


def greedy_generate(params, cfg: ModelConfig, cache, first_token,
                    n_tokens: int, step=None):
    """Host-loop generation used by examples/tests (not the dry-run).

    `step`: a prebuilt jitted serve step — pass one fetched from a
    GroupPool executable cache (as `Engine.serve` does) so repeated
    serve calls on the same (batch, cache) shape reuse the compiled
    artifact instead of re-jitting per call."""
    if step is None:
        step = jax.jit(make_serve_step(cfg))
    tok = first_token
    out = []
    for _ in range(n_tokens):
        tok, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.stack(out, axis=1), cache
