"""Synthetic serving traces from the paper's length distributions.

The same dataset profiles that drive training heterogeneity
(core/dataset_profiles.py, paper Fig. 1) generate serving prompts — a
request's "prompt" stands in for a multimodal context whose token count
follows the dataset's long tail, and whose MODALITY LAYOUT follows the
dataset's span convention (interleaved vision frames for OpenVid/
InternVid, an audio-prefix window for MSRVTT). Requests therefore carry
`ModalitySpan`s: the serving scheduler plans chunked prefill against
per-chunk derived eta and masks bidirectional blocks correctly, instead
of treating every prompt as causal text. Output lengths and Poisson
arrivals are drawn independently so a trace exercises both dimensions
continuous batching exploits: ragged prefill cost and ragged decode
lifetimes.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..core.cost_model import (ATTN_CAUSAL, ModalitySpan, slice_spans,
                               spans_eta)
from ..core.distributions import sample_mm_batch
from .scheduler import ServeRequest


def sample_trace(
    dataset: str,
    n: int,
    rng: np.random.Generator,
    *,
    vocab: int = 1024,
    max_prompt: int = 256,
    min_prompt: int = 4,
    mean_new_tokens: int = 16,
    max_new_tokens: int = 64,
    arrival_rate: Optional[float] = None,
    tokens_per_frame: int = 16,
    deadline_s: Optional[float] = None,
    with_spans: bool = True,
) -> List[ServeRequest]:
    """Draw `n` requests with heterogeneous prompt/output lengths.

    Prompt lengths come from the dataset's duration distribution
    (clipped to [min_prompt, max_prompt]); output lengths are geometric
    with mean `mean_new_tokens` (clipped to max_new_tokens) — the
    classic heavy-tailed decode-lifetime model; arrivals are Poisson
    with `arrival_rate` requests/s (None = everything arrives at t=0,
    the closed-batch case benchmarks use). `with_spans=False` strips
    the modality layout (legacy causal-prompt traces).
    """
    mms = sample_mm_batch(dataset, n, rng, max_tokens=max_prompt,
                          tokens_per_frame=tokens_per_frame)
    arrival = 0.0
    out: List[ServeRequest] = []
    for i, mm in enumerate(mms):
        prompt_len = max(min_prompt, min(mm.length, max_prompt))
        spans = slice_spans(mm.spans, 0, min(prompt_len, mm.length))
        if prompt_len > mm.length:
            # min_prompt padding joins the trailing causal text
            spans = spans + (ModalitySpan(
                "text", mm.length, prompt_len - mm.length, ATTN_CAUSAL),)
        tokens = rng.integers(0, vocab, size=prompt_len, dtype=np.int32)
        new = int(np.clip(rng.geometric(1.0 / max(mean_new_tokens, 1)),
                          1, max_new_tokens))
        if arrival_rate:
            arrival += float(rng.exponential(1.0 / arrival_rate))
        out.append(ServeRequest(
            request_id=i, tokens=tokens, max_new_tokens=new,
            arrival_s=arrival,
            deadline_s=(arrival + deadline_s) if deadline_s else None,
            eta=spans_eta(spans),
            spans=spans if with_spans else None))
    return out
