"""Synthetic serving traces from the paper's length distributions.

The same truncated-lognormal video-duration model that drives training
heterogeneity (core/distributions.py, paper Fig. 1) generates serving
prompt lengths — a request's "prompt" stands in for a multimodal context
whose token count follows the dataset's long tail. Output lengths and
Poisson arrivals are drawn independently so a trace exercises both
dimensions continuous batching exploits: ragged prefill cost and ragged
decode lifetimes.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from ..core.distributions import sample_batch
from .scheduler import ServeRequest


def sample_trace(
    dataset: str,
    n: int,
    rng: np.random.Generator,
    *,
    vocab: int = 1024,
    max_prompt: int = 256,
    min_prompt: int = 4,
    mean_new_tokens: int = 16,
    max_new_tokens: int = 64,
    arrival_rate: Optional[float] = None,
    tokens_per_frame: int = 16,
    deadline_s: Optional[float] = None,
) -> List[ServeRequest]:
    """Draw `n` requests with heterogeneous prompt/output lengths.

    Prompt lengths come from the dataset's duration distribution
    (clipped to [min_prompt, max_prompt]); output lengths are geometric
    with mean `mean_new_tokens` (clipped to max_new_tokens) — the
    classic heavy-tailed decode-lifetime model; arrivals are Poisson
    with `arrival_rate` requests/s (None = everything arrives at t=0,
    the closed-batch case benchmarks use).
    """
    infos = sample_batch(dataset, n, rng, max_tokens=max_prompt,
                         tokens_per_frame=tokens_per_frame)
    arrival = 0.0
    out: List[ServeRequest] = []
    for i, info in enumerate(infos):
        prompt_len = max(min_prompt, min(info.length, max_prompt))
        tokens = rng.integers(0, vocab, size=prompt_len, dtype=np.int32)
        new = int(np.clip(rng.geometric(1.0 / max(mean_new_tokens, 1)),
                          1, max_new_tokens))
        if arrival_rate:
            arrival += float(rng.exponential(1.0 / arrival_rate))
        out.append(ServeRequest(
            request_id=i, tokens=tokens, max_new_tokens=new,
            arrival_s=arrival,
            deadline_s=(arrival + deadline_s) if deadline_s else None,
            eta=info.eta))
    return out
