"""whisper-small [arXiv:2212.04356]
12L d_model=768 12H d_ff=3072 vocab=51865; enc-dec, conv frontend stubbed
(input_specs provides precomputed frame embeddings)."""
from .base import EncDecCfg, ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, kv_heads=12,
    d_ff=3072, vocab=51865, activation="gelu", use_rope=False,
    encdec=EncDecCfg(n_enc_layers=12, n_audio_frames=1500),
    source="arXiv:2212.04356",
)
