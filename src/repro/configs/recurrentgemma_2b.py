"""recurrentgemma-2b [arXiv:2402.19427]
26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000; RG-LRU + local
attention, pattern (rec, rec, attn), window 2048."""
from .base import HybridCfg, ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, kv_heads=1,
    d_ff=7680, vocab=256000, rope_theta=10_000.0,
    hybrid=HybridCfg(pattern=("rec", "rec", "attn"), lru_width=2560,
                     window=2048),
    source="arXiv:2402.19427",
)
