"""olmoe-1b-7b [arXiv:2409.02060]
16L d_model=2048 16H (GQA kv=16) d_ff=1024/expert vocab=50304, MoE 64e top-8."""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoECfg(n_experts=64, top_k=8, expert_ff=1024,
               dispatch="sort"),  # §Perf G1/G2 (einsum = baseline)
    source="arXiv:2409.02060",
)
