"""glm4-9b [hf:THUDM/glm-4-9b]
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552; RoPE, GQA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, kv_heads=2,
    d_ff=13696, vocab=151552, rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b",
)
