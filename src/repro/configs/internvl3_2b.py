"""InternVL3-2B — the paper's own workload (Table 5): 28L 12H (GQA kv=2)
d_model=1536, vision hidden 1024 (ViT stubbed). Used by the DHP
end-to-end examples and simulator calibration."""
from .base import ModelConfig, VLMCfg

CONFIG = ModelConfig(
    arch_id="internvl3-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, kv_heads=2,
    d_ff=8960, vocab=151674,
    vlm=VLMCfg(vision_dim=1024, patches_per_seq_frac=0.5),
    source="paper Table 5 / arXiv:2312.14238",
)
