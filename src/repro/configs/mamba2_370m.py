"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality)
48L d_model=1024 attn-free, ssm_state=128, vocab=50280."""
from .base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    arch_id="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, kv_heads=0,
    d_ff=0, vocab=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=256),
    source="arXiv:2405.21060",
)
