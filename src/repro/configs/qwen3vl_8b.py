"""Qwen3-VL-8B — the paper's largest workload (Table 5): 36L 32H (GQA kv=8)
d_model=4096, vision hidden 1152 (ViT stubbed)."""
from .base import ModelConfig, VLMCfg

CONFIG = ModelConfig(
    arch_id="qwen3vl-8b", family="vlm",
    n_layers=36, d_model=4096, n_heads=32, kv_heads=8,
    d_ff=12288, vocab=151674,
    vlm=VLMCfg(vision_dim=1152, patches_per_seq_frac=0.5),
    source="paper Table 5 / arXiv:2511.21631",
)
