"""Model / run configuration system.

One frozen dataclass describes every architecture in the assigned pool;
family-specific knobs live in optional sub-fields. `ModelConfig.reduced()`
derives the CPU smoke-test variant (2 layers, d_model <= 512, <= 4
experts) required per architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    expert_ff: int            # d_ff of each expert
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    dispatch: str = "einsum"  # einsum (one-hot baseline) | sort (O(T·k·D))
    dispatch_group: int = 8192  # sort: tokens per shard-local dispatch
                                # group (0 = one global group)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2           # d_inner = expand * d_model
    chunk: int = 256          # SSD chunk length
    conv_width: int = 4
    dt_min: float = 1e-3
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class HybridCfg:
    # RecurrentGemma / Griffin: pattern unit (rec, rec, attn)
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_width: Optional[int] = None   # defaults to d_model
    window: int = 2048                # local attention window
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    """Whisper-style encoder-decoder; encoder consumes stub frame embeds."""
    n_enc_layers: int = 12
    n_audio_frames: int = 1500        # conv-frontend output length (stub)


@dataclasses.dataclass(frozen=True)
class VLMCfg:
    """Pixtral-style VLM; ViT frontend is a stub providing patch embeds."""
    vision_dim: int = 1024            # stub patch-embedding dim
    patches_per_seq_frac: float = 0.25  # fraction of seq positions = image


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    source: str = ""          # paper / model-card citation

    head_dim: Optional[int] = None      # default d_model // n_heads
    rope_theta: float = 500_000.0
    rope_2d: bool = False               # chatglm3 partial-rotary style
    use_rope: bool = True               # False: absolute sinusoidal (whisper)
    norm_eps: float = 1e-5
    activation: str = "swiglu"          # swiglu | gelu
    tie_embeddings: bool = False

    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid: Optional[HybridCfg] = None
    encdec: Optional[EncDecCfg] = None
    vlm: Optional[VLMCfg] = None

    # attention behaviour
    sliding_window: Optional[int] = None    # sub-quadratic variant (decode)
    attn_impl: str = "chunked"              # reference | chunked | pallas
    cp_axis: Optional[str] = None           # ring-CP mesh axis (shard_map)
    remat: bool = True                      # activation checkpoint per layer
    param_dtype: str = "bfloat16"
    scan_layers: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_decode_capable(self) -> bool:
        return True   # all assigned archs have a decoder

    def sub_quadratic(self) -> bool:
        """Can this arch decode a 500k context without O(L) full KV attn?"""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        kv = max(1, min(self.kv_heads, n_heads))
        while n_heads % kv:
            kv -= 1
        kw = dict(
            n_layers=2 if self.family != "hybrid" else 3,
            d_model=d_model,
            n_heads=n_heads,
            kv_heads=kv,
            head_dim=None,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 1024),
            param_dtype="float32",
            attn_impl="reference",
            remat=False,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                expert_ff=min(self.moe.expert_ff, 256))
        if self.ssm:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk=32)
        if self.hybrid:
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, lru_width=d_model, window=64)
        if self.encdec:
            kw["encdec"] = dataclasses.replace(
                self.encdec, n_enc_layers=2, n_audio_frames=16)
        if self.vlm:
            kw["vlm"] = dataclasses.replace(self.vlm, vision_dim=64)
        return self.with_(**kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                    LONG_500K)}
