"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]
24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, MoE 32e top-8."""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, kv_heads=8,
    d_ff=512, vocab=49155,
    moe=MoECfg(n_experts=32, top_k=8, expert_ff=512,
               dispatch="sort"),  # §Perf G1/G2 (einsum = baseline)
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
