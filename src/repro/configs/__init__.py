"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import (INPUT_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K,
                   TRAIN_4K, InputShape, ModelConfig)

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama3-405b": "llama3_405b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "whisper-small": "whisper_small",
    "minitron-4b": "minitron_4b",
    "glm4-9b": "glm4_9b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "chatglm3-6b": "chatglm3_6b",
    "mamba2-370m": "mamba2_370m",
    "pixtral-12b": "pixtral_12b",
    # the paper's own workloads
    "internvl3-2b": "internvl3_2b",
    "qwen3vl-8b": "qwen3vl_8b",
}

ASSIGNED_ARCHS = list(_MODULES)[:10]
ALL_ARCHS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ALL_ARCHS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ALL_ARCHS}


__all__ = ["ModelConfig", "InputShape", "INPUT_SHAPES", "TRAIN_4K",
           "PREFILL_32K", "DECODE_32K", "LONG_500K", "get_config",
           "all_configs", "ASSIGNED_ARCHS", "ALL_ARCHS"]
