"""pixtral-12b [hf:mistralai/Pixtral-12B-2409]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072; pixtral-ViT
frontend stubbed (input_specs provides patch embeddings)."""
from .base import ModelConfig, VLMCfg

CONFIG = ModelConfig(
    arch_id="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, kv_heads=8,
    d_ff=14336, vocab=131072,
    vlm=VLMCfg(vision_dim=1024, patches_per_seq_frac=0.25),
    source="hf:mistralai/Pixtral-12B-2409",
)
