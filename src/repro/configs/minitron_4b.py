"""minitron-4b [arXiv:2407.14679] — pruned nemotron
32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, kv_heads=8,
    d_ff=9216, vocab=256000,
    source="arXiv:2407.14679",
)
