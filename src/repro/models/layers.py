"""Shared neural building blocks (pure functional JAX).

Parameters are plain nested dicts of jnp arrays; every block exposes
`init_*(key, ...) -> params` and a pure apply function. Weight layouts
are chosen so the `model` mesh axis can shard the obvious contracting
dimensions (heads / ffn / experts) — see parallel/sharding.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(orig)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    out = x * params["scale"].astype(jnp.float32) + params["bias"].astype(
        jnp.float32)
    return out.astype(orig)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, d_model, d_ff, dtype),
         "down": dense_init(k2, d_ff, d_model, dtype)}
    if activation == "swiglu":
        p["gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def mlp(params: dict, x: jax.Array, activation: str) -> jax.Array:
    up = x @ params["up"]
    if activation == "swiglu":
        gate = jax.nn.silu(x @ params["gate"])
        h = gate * up
    else:
        h = jax.nn.gelu(up)
    return h @ params["down"]


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jax.Array, x: jax.Array,
            tied: bool) -> jax.Array:
    if tied:
        return x @ table_or_head.T
    return x @ table_or_head


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float,
                     rotary_frac: float = 1.0) -> jax.Array:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * rotary_frac) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rotary_frac: float = 1.0) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S].

    rotary_frac < 1 rotates only the leading fraction of each head
    (ChatGLM-style 2D/partial rotary); the remainder passes through.
    """
    d = x.shape[-1]
    inv = rope_frequencies(d, theta, rotary_frac)
    rot = inv.shape[0] * 2
    if rot == 0:                # rotary disabled (absolute-pos models)
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]    # broadcast over heads
    cos = cos[..., :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(*xr.shape)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out
