"""Mixture-of-Experts FFN: top-k router + capacity-bounded dispatch.

Expert weights are stacked [E, d_model, d_ff] so the `model` mesh axis
shards the EXPERT dimension (expert parallelism) — XLA then inserts the
all-to-all-equivalent collectives for the dispatch/combine einsums.
Dispatch uses the standard capacity-factor one-hot formulation (tokens
over capacity are dropped, residual passthrough keeps them alive), plus
the switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(key, d_model: int, n_experts: int, expert_ff: int,
             dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "gate": (jax.random.normal(kg, (n_experts, d_model, expert_ff),
                                   jnp.float32) / jnp.sqrt(d_model)
                 ).astype(dtype),
        "up": (jax.random.normal(ku, (n_experts, d_model, expert_ff),
                                 jnp.float32) / jnp.sqrt(d_model)
               ).astype(dtype),
        "down": (jax.random.normal(kd, (n_experts, expert_ff, d_model),
                                   jnp.float32) / jnp.sqrt(expert_ff)
                 ).astype(dtype),
    }


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25,
            dispatch: str = "sort",
            dispatch_group: int = 0,
            ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,D] -> (out [B,S,D], aux_loss scalar).

    `dispatch`:
      "sort"   — argsort-based gather/scatter dispatch, O(T·k·D) data
                 movement and zero dispatch FLOPs (the TPU-native path;
                 see EXPERIMENTS.md §Perf iteration G1).
      "einsum" — classic Mesh-TF one-hot formulation: builds a
                 [T,E,cap] dispatch tensor, whose einsums cost
                 O(T·E·cap·D) = O(T²·D·k·cf/1) FLOPs — quadratic in
                 tokens. Kept as the reference/baseline.
    Both paths implement identical capacity semantics (first-come
    queueing in token order, dropped tokens ride the residual).
    """
    B, S, D = x.shape
    E = params["router"].shape[1]
    T = B * S
    xt = x.reshape(T, D)

    logits = (xt.astype(jnp.float32) @ params["router"])      # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)               # [T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)                                         # [E]
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(
        1.0 / (T * top_k))
    aux = E * jnp.sum(me * ce)

    cap = int(capacity_factor * T * top_k / E) or 1

    if dispatch == "sort":
        # Dispatch in groups of <= dispatch_group tokens. Group
        # boundaries align with the batch/sequence sharding (B·S
        # flatten), so each group's argsort/scatter stays shard-local —
        # the global variant all-gathers the whole [E·cap, D] expert
        # buffer across the data axis (§Perf iteration G2). Capacity is
        # per-group (cap_g = cf·Tg·k/E), the same semantics at
        # dispatch_group >= T as the global einsum reference.
        Tg = dispatch_group or T
        while T % Tg:                     # largest divisor <= requested
            Tg -= 1
        G = T // Tg
        cap_g = int(capacity_factor * Tg * top_k / E) or 1
        out = jax.vmap(
            lambda xg, ig, gg: _moe_sort_dispatch(params, xg, ig, gg,
                                                  cap_g)
        )(xt.reshape(G, Tg, D), idx.reshape(G, Tg, top_k),
          gate_vals.reshape(G, Tg, top_k))
        return out.reshape(B, S, D), aux

    # ---------------- reference einsum path ----------------
    # position of each (token, slot) within its expert's queue
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)           # [T,k,E]
    flat = onehot.reshape(T * top_k, E)
    pos = jnp.cumsum(flat, axis=0) - 1                         # queue index
    pos = (pos * flat).sum(-1).reshape(T, top_k)               # [T,k]
    keep = pos < cap
    gate_vals = gate_vals * keep

    # dispatch [T,k,E,cap] one-hot (bool) -> expert inputs [E,cap,D]
    disp = (jax.nn.one_hot(idx, E, dtype=xt.dtype)[..., :, None]
            * jax.nn.one_hot(pos, cap, dtype=xt.dtype)[..., None, :]
            * keep[..., None, None])                           # [T,k,E,cap]
    disp = disp.sum(1)                                         # [T,E,cap]
    ex_in = jnp.einsum("td,tec->ecd", xt, disp)                # [E,cap,D]

    ex_out = _expert_mlps(params, ex_in)                       # [E,cap,D]

    comb = jnp.einsum("tec,ecd->ted", disp, ex_out)            # [T,E,D]
    # weighted combine: sum_k gate * expert_out(token)
    gate_e = (jax.nn.one_hot(idx, E, dtype=xt.dtype)
              * gate_vals[..., None].astype(xt.dtype)).sum(1)  # [T,E]
    out = jnp.einsum("te,ted->td", gate_e, comb)
    return out.reshape(B, S, D), aux


def _expert_mlps(params: dict, ex_in: jax.Array) -> jax.Array:
    """[E,cap,D] -> [E,cap,D] through each expert's SwiGLU MLP."""
    h = jnp.einsum("ecd,edf->ecf", ex_in, params["gate"])
    u = jnp.einsum("ecd,edf->ecf", ex_in, params["up"])
    h = jax.nn.silu(h) * u
    return jnp.einsum("ecf,efd->ecd", h, params["down"])       # [E,cap,D]


def _moe_sort_dispatch(params: dict, xt: jax.Array, idx: jax.Array,
                       gate_vals: jax.Array, cap: int) -> jax.Array:
    """Sort-based dispatch: gather tokens into [E,cap,D] expert buffers
    via a stable argsort over expert ids — no [T,E,cap] tensor, no
    dispatch matmuls. Identical capacity semantics to the one-hot path
    (queue position = arrival order of (token, slot) pairs)."""
    T, D = xt.shape
    E = params["router"].shape[1]
    k = idx.shape[1]
    S = T * k                                                  # slots

    slot_expert = idx.reshape(S)                               # [S]
    order = jnp.argsort(slot_expert, stable=True)              # [S]
    # rank of each slot in the sorted order, then queue position
    # within its expert = rank - (# slots of smaller expert ids)
    rank = jnp.zeros((S,), jnp.int32).at[order].set(
        jnp.arange(S, dtype=jnp.int32))
    counts = jnp.bincount(slot_expert, length=E)               # [E]
    starts = jnp.cumsum(counts) - counts                       # [E]
    pos = rank - starts[slot_expert]                           # [S]
    keep = pos < cap
    gate_kept = (gate_vals.reshape(S) * keep).astype(xt.dtype)

    # scatter tokens into expert buffers (unique (e,pos) per kept slot)
    buf_idx = jnp.where(keep, slot_expert * cap + pos, E * cap)  # drop row
    token_of_slot = jnp.arange(S, dtype=jnp.int32) // k
    ex_in = jnp.zeros((E * cap + 1, D), xt.dtype).at[buf_idx].set(
        xt[token_of_slot], mode="drop")
    ex_out = _expert_mlps(params, ex_in[:E * cap].reshape(E, cap, D))

    # gather back: each kept slot reads its expert-buffer row
    slot_out = ex_out.reshape(E * cap, D)[
        jnp.clip(buf_idx, 0, E * cap - 1)]                     # [S,D]
    slot_out = slot_out * gate_kept[:, None]
    out = jnp.zeros((T, D), xt.dtype).at[token_of_slot].add(slot_out)
    return out
