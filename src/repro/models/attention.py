"""Attention: GQA projections + three mask modes x three implementations.

Implementations:
  * reference — full score matrix (smoke tests, tiny shapes).
  * chunked   — lax.scan online-softmax over KV blocks (flash-style in
                pure JAX): O(chunk * S) live memory. This is the default
                for dry-runs/large shapes — the compiled HLO stays small
                (one block's compute, scanned).
  * banded    — sliding-window attention, O(S * window) compute: the
                sub-quadratic variant that qualifies dense archs for the
                long_500k decode shape.
  * pallas    — the TPU kernel in kernels/ (selected via cfg.attn_impl).

Modes: "causal" (LLM), "full" (vision/audio encoder — the eta factor of
DHP Eq. 8), "sliding" (RecurrentGemma local attention / long-context
variant).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, dense_init

NEG_INF = -1e30


def init_attention(key, d_model: int, n_heads: int, kv_heads: int,
                   head_dim: int, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(kk, d_model, kv_heads * head_dim, dtype),
        "wv": dense_init(kv, d_model, kv_heads * head_dim, dtype),
        "wo": dense_init(ko, n_heads * head_dim, d_model, dtype),
    }


# --------------------------------------------------------------------------
# Cores. q: [B,S,H,D], k/v: [B,T,Hkv,D]. Positions are absolute.
# --------------------------------------------------------------------------
def _pos_mask(qpos, kpos, mode: str, window: Optional[int]):
    """[S,T] positional (causal/full/sliding) boolean mask."""
    if mode == "full":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = kpos[None, :] <= qpos[:, None]
    if mode == "sliding":
        assert window is not None
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


def _mask_bias(qpos, kpos, mode: str, window: Optional[int]):
    """[S,T] additive bias in fp32."""
    return jnp.where(_pos_mask(qpos, kpos, mode, window), 0.0, NEG_INF)


def _span_mask(span_q, span_k):
    """[B,S,T] bool: (q, k) lie in the SAME bidirectional modality
    block. span ids >= 0 name a block (vision frame / audio window);
    -1 marks causal text and padding. OR-ing this into the positional
    mask lets block members attend FORWARD within their block — the
    mixed mask of DHP Eq. 8."""
    return (span_q[:, :, None] >= 0) \
        & (span_q[:, :, None] == span_k[:, None, :])


def _segment_bias(seg_q, seg_k):
    """[B,S,T] additive bias: NEG_INF across segment boundaries.

    seg < 0 marks tail padding — it never attends nor is attended."""
    same = (seg_q[:, :, None] == seg_k[:, None, :]) \
        & (seg_q >= 0)[:, :, None]
    return jnp.where(same, 0.0, NEG_INF)


def _norm_table(t, B, S, dtype=jnp.int32):
    """[S] or [B,S] id table -> [B,S] in `dtype`."""
    t = jnp.asarray(t, dtype)
    if t.ndim == 1:
        t = jnp.broadcast_to(t[None], (B, S))
    return t


def attn_reference(q, k, v, *, mode: str, window=None, q_offset=0,
                   kv_offset=0, segment_ids=None, span_ids=None):
    """`span_ids` ([B,S] or [S] int32; -1 = causal) adds the mixed mask:
    tokens sharing a nonnegative span id attend bidirectionally within
    the block, embedded in the otherwise causal/sliding stream."""
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, kf) / math.sqrt(D)
    qpos = q_offset + jnp.arange(S)
    kpos = kv_offset + jnp.arange(T)
    allowed = jnp.broadcast_to(
        _pos_mask(qpos, kpos, mode, window)[None], (B, S, T))
    if span_ids is not None:
        assert T == S, "span-masked attention is self-attention"
        sp = _norm_table(span_ids, B, S)
        allowed = allowed | _span_mask(sp, sp)
    seg = None
    if segment_ids is not None:
        seg = _norm_table(segment_ids, B, S)
        allowed = allowed & (seg[:, :, None] == seg[:, None, :]) \
            & (seg >= 0)[:, :, None]
    s = s + jnp.where(allowed, 0.0, NEG_INF)[:, :, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(jnp.float32))
    if seg is not None:
        # tail-padding rows (seg < 0) have no attendable key: emit exact
        # zeros like every other packed implementation, instead of the
        # uniform softmax over an all-NEG_INF row
        o = jnp.where((seg >= 0)[:, :, None, None, None], o, 0.0)
    return o.reshape(B, S, H, D).astype(q.dtype)


def _kv_blocks(k, v, chunk):
    B, T, Hkv, D = k.shape
    pad = (-T) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_blk = (T + pad) // chunk
    kb = k.reshape(B, n_blk, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blk, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    return kb, vb, n_blk


@partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11))
def _attn_chunked_core(q, k, v, seg_q, seg_k, span_q, span_k, mode,
                       window, q_offset, kv_offset, chunk):
    """Flash attention in pure JAX: online-softmax scan over KV chunks,
    with a custom VJP that RECOMPUTES the probability tiles per chunk in
    the backward pass (flash-attention-2 backward). Live memory is
    O(S*chunk), forward and backward — the property the Pallas kernel
    has on TPU, preserved in the portable path.

    `seg_q`/`seg_k` (None, or float32 [B,S]/[B,T] with -1 = padding)
    switch on packed-varlen masking: attention becomes block-diagonal
    over segments. `span_q`/`span_k` (same convention) switch on the
    mixed modality mask: same-id tokens attend bidirectionally within
    their block. Float dtype so all tables ride through the custom VJP
    as ordinary primals with zero cotangents."""
    o, _ = _attn_chunked_fwd_impl(q, k, v, seg_q, seg_k, span_q, span_k,
                                  mode, window, q_offset, kv_offset,
                                  chunk)
    return o


def attn_chunked(q, k, v, *, mode: str = "causal", window=None,
                 q_offset=0, kv_offset=0, chunk: int = 1024,
                 segment_ids=None, span_ids=None):
    seg_q = seg_k = span_q = span_k = None
    if segment_ids is not None:
        assert k.shape[1] == q.shape[1], \
            "packed segments require self-attention (Sk == Sq)"
        seg_q = seg_k = _norm_table(segment_ids, q.shape[0], q.shape[1],
                                    jnp.float32)
    if span_ids is not None:
        assert k.shape[1] == q.shape[1], \
            "modality spans require self-attention (Sk == Sq)"
        span_q = span_k = _norm_table(span_ids, q.shape[0], q.shape[1],
                                      jnp.float32)
    return _attn_chunked_core(q, k, v, seg_q, seg_k, span_q, span_k,
                              mode, window, q_offset, kv_offset, chunk)


def _seg_chunks(seg_k, chunk, n_blk):
    """[B,T] float seg table -> [n_blk, B, chunk] scan slices."""
    B, T = seg_k.shape
    pad = n_blk * chunk - T
    segp = jnp.pad(seg_k, ((0, 0), (0, pad)), constant_values=-1.0)
    return segp.reshape(B, n_blk, chunk).transpose(1, 0, 2)


def _chunk_bias_seg(qpos, i, chunk, T, mode, window, kv_offset,
                    seg_q, seg_kc, span_q=None, span_kc=None):
    """[B or 1, S, chunk] bias: positional mask, OR'd with the
    bidirectional-block mask, AND'd with the segment mask."""
    kpos = kv_offset + i * chunk + jnp.arange(chunk)
    allowed = (_pos_mask(qpos, kpos, mode, window)
               & (kpos[None, :] < kv_offset + T))[None]
    if span_q is not None:
        # span tables pad with -1, so padded KV slots never match
        allowed = allowed | _span_mask(span_q, span_kc)
    if seg_q is not None:
        allowed = allowed & (seg_q[:, :, None] == seg_kc[:, None, :]) \
            & (seg_q >= 0)[:, :, None]
    return jnp.where(allowed, 0.0, NEG_INF)


def _table_chunks(tab, chunk, n_blk):
    return (_seg_chunks(tab, chunk, n_blk) if tab is not None
            else jnp.zeros((n_blk, 1, 1)))


def _attn_chunked_fwd_impl(q, k, v, seg_q, seg_k, span_q, span_k, mode,
                           window, q_offset, kv_offset, chunk):
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    chunk = min(chunk, T)
    kb, vb, n_blk = _kv_blocks(k, v, chunk)
    segb = _table_chunks(seg_k, chunk, n_blk)
    spanb = _table_chunks(span_k, chunk, n_blk)
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    qpos = q_offset + jnp.arange(S)

    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, S, Hkv, G, D), jnp.float32)

    def body(carry, blk):
        m, l, acc = carry
        kc, vc, i, segc, spanc = blk
        s = jnp.einsum("bskgd,btkd->bskgt", qg,
                       kc.astype(jnp.float32)) * scale
        s = s + _chunk_bias_seg(
            qpos, i, chunk, T, mode, window, kv_offset, seg_q, segc,
            span_q, spanc)[:, :, None, None, :]
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bskgt,btkd->bskgd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kb, vb, jnp.arange(n_blk), segb, spanb))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))           # [B,S,Hkv,G]
    o = acc / jnp.maximum(l[..., None], 1e-30)
    out = o.reshape(B, S, H, D).astype(q.dtype)
    return out, lse


def _attn_chunked_fwd(q, k, v, seg_q, seg_k, span_q, span_k, mode,
                      window, q_offset, kv_offset, chunk):
    out, lse = _attn_chunked_fwd_impl(q, k, v, seg_q, seg_k, span_q,
                                      span_k, mode, window, q_offset,
                                      kv_offset, chunk)
    return out, (q, k, v, seg_q, seg_k, span_q, span_k, out, lse)


def _attn_chunked_bwd(mode, window, q_offset, kv_offset, chunk, res, g):
    q, k, v, seg_q, seg_k, span_q, span_k, out, lse = res
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    chunk = min(chunk, T)
    kb, vb, n_blk = _kv_blocks(k, v, chunk)
    segb = _table_chunks(seg_k, chunk, n_blk)
    spanb = _table_chunks(span_k, chunk, n_blk)
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    gg = g.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    og = out.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    delta = jnp.sum(gg * og, axis=-1)                   # [B,S,Hkv,G]
    qpos = q_offset + jnp.arange(S)

    def body(dq, blk):
        kc, vc, i, segc, spanc = blk
        s = jnp.einsum("bskgd,btkd->bskgt", qg,
                       kc.astype(jnp.float32)) * scale
        s = s + _chunk_bias_seg(
            qpos, i, chunk, T, mode, window, kv_offset, seg_q, segc,
            span_q, spanc)[:, :, None, None, :]
        p = jnp.exp(s - lse[..., None])                 # recomputed tile
        dv = jnp.einsum("bskgt,bskgd->btkd", p, gg)
        dp = jnp.einsum("bskgd,btkd->bskgt", gg, vc.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bskgt,btkd->bskgd", ds,
                             kc.astype(jnp.float32))
        dk = jnp.einsum("bskgt,bskgd->btkd", ds, qg)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, S, Hkv, G, D), jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(
        body, dq0, (kb, vb, jnp.arange(n_blk), segb, spanb))
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, n_blk * chunk, Hkv, D)
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, n_blk * chunk, Hkv, D)
    zero_like = lambda t: None if t is None else jnp.zeros_like(t)  # noqa: E731
    return (dq.reshape(B, S, H, D).astype(q.dtype),
            dk[:, :T].astype(k.dtype), dv[:, :T].astype(v.dtype),
            zero_like(seg_q), zero_like(seg_k),
            zero_like(span_q), zero_like(span_k))


_attn_chunked_core.defvjp(_attn_chunked_fwd, _attn_chunked_bwd)


def attn_banded(q, k, v, *, window: int, q_offset=0, chunk: int = 512):
    """Sliding-window attention with O(S*window) compute.

    K/V are front-padded by w_pad = ceil(window/chunk)*chunk so every q
    block attends a static-size [w_pad + chunk] kv slice starting at its
    own block offset — compute is truly banded, not masked-out.
    """
    B, S, H, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    assert S == T, "banded core is for self-attention (prefill/train)"
    G = H // Hkv
    chunk = min(chunk, S)
    pad_s = (-S) % chunk
    if pad_s:
        q = jnp.pad(q, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
    Sp = S + pad_s
    n_blk = Sp // chunk
    w_pad = -(-window // chunk) * chunk
    kp = jnp.pad(k, ((0, 0), (w_pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w_pad, 0), (0, 0), (0, 0)))

    qb = q.reshape(B, n_blk, chunk, H, D).transpose(1, 0, 2, 3, 4)

    def block(i, qc):
        # kv slice covering positions [i*chunk - w_pad, i*chunk + chunk)
        kc = jax.lax.dynamic_slice_in_dim(kp, i * chunk, w_pad + chunk, 1)
        vc = jax.lax.dynamic_slice_in_dim(vp, i * chunk, w_pad + chunk, 1)
        qg = (qc.reshape(B, chunk, Hkv, G, D)
              / math.sqrt(D)).astype(jnp.float32)
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kc.astype(jnp.float32))
        qpos = q_offset + i * chunk + jnp.arange(chunk)
        kpos = q_offset + i * chunk - w_pad + jnp.arange(w_pad + chunk)
        bias = _mask_bias(qpos, kpos, "sliding", window)
        # mask front padding & tail padding
        valid = (kpos >= q_offset) & (kpos < q_offset + S)
        bias = jnp.where(valid[None, :], bias, NEG_INF)
        s = s + bias[None, :, None, None, :]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bskgt,btkd->bskgd", p, vc.astype(jnp.float32))
        return o.reshape(B, chunk, H, D)

    def body(_, blk):
        i, qc = blk
        return None, block(i, qc)

    _, ob = jax.lax.scan(body, None, (jnp.arange(n_blk), qb))
    o = ob.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, D)
    return o[:, :S].astype(q.dtype)


def attn_decode(q1, k_cache, v_cache, valid_len, *, mode: str = "causal",
                window: Optional[int] = None):
    """One-token decode: q1 [B,1,H,D] vs cache [B,T,Hkv,D].

    `valid_len` [B] — number of live cache entries. For sliding-window
    caches the ring buffer already holds only the window, so every live
    entry is attendable.
    """
    B, _, H, D = q1.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = (q1.reshape(B, 1, Hkv, G, D) / math.sqrt(D)).astype(jnp.float32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, k_cache.astype(jnp.float32))
    live = jnp.arange(T)[None, :] < valid_len[:, None]        # [B,T]
    s = jnp.where(live[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q1.dtype)


def attn_prefill_chunk(q, k_cache, v_cache, start_pos,
                       chunk_span_ids=None, cache_span_ids=None):
    """Chunked-prefill attention: q [B,C,H,D] at absolute positions
    start_pos..start_pos+C-1 vs a KV cache [B,T,Hkv,D] whose rows
    [0, start_pos+C) are live (the chunk's own K/V must already be
    written at its positions). Causal over absolute position: query i
    attends cache rows j <= start_pos + i.

    Rows past the live region are never attended (j > start_pos + i for
    every query in the chunk), so garbage beyond the written prefix —
    e.g. padding rows of a bucketed final chunk — cannot leak in.

    `chunk_span_ids` [B,C] / `cache_span_ids` [B,T] (int32, -1 = causal)
    switch on the mixed modality mask: a query inside a bidirectional
    block (vision frame / audio window) additionally attends FORWARD to
    same-block cache rows, restricted to the written region
    [0, start_pos+C) — exact as long as the serving scheduler never
    splits a bidirectional span across chunks (it snaps chunk
    boundaries to span ends).
    """
    B, C, H, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qg = (q.reshape(B, C, Hkv, G, D) / math.sqrt(D)).astype(jnp.float32)
    s = jnp.einsum("bckgd,btkd->bckgt", qg, k_cache.astype(jnp.float32))
    qpos = start_pos + jnp.arange(C)                           # [C]
    live = jnp.arange(T)[None, :] <= qpos[:, None]             # [C,T]
    allowed = jnp.broadcast_to(live[None], (B, C, T))
    if chunk_span_ids is not None:
        bidir = _span_mask(jnp.asarray(chunk_span_ids, jnp.int32),
                           jnp.asarray(cache_span_ids, jnp.int32))
        written = jnp.arange(T)[None, None, :] < start_pos + C
        allowed = allowed | (bidir & written)
    s = jnp.where(allowed[:, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bckgt,btkd->bckgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, C, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Full attention block (projections + rope + core dispatch)
# --------------------------------------------------------------------------
def attention(params: dict, x: jax.Array, *, n_heads: int, kv_heads: int,
              head_dim: int, rope_theta: float, positions=None,
              mode: str = "causal", window: Optional[int] = None,
              impl: str = "chunked", rope_frac: float = 1.0,
              cross_kv: Optional[tuple] = None,
              cp_axis: Optional[str] = None,
              attn_chunk: int = 1024,
              segment_ids=None,
              span_ids=None,
              return_kv: bool = False):
    """`segment_ids` ([B,S] int32, -1 = padding) selects the packed
    varlen path: x is a packed buffer of concatenated sequences and
    attention is block-diagonal over segments (causal/full/sliding
    *within* each). Pass per-segment-reset `positions` so RoPE matches.

    `span_ids` ([B,S] int32, -1 = causal) switches on the mixed
    modality mask: tokens sharing a nonnegative id form a bidirectional
    block (vision frame / audio window) embedded in the causal stream —
    composable with `segment_ids` (blocks never cross segments by
    construction) and with any impl, including the ring-CP path where
    the table rides the ppermute hops.
    """
    B, S, _ = x.shape
    q = (x @ params["wq"]).reshape(B, S, n_heads, head_dim)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, S, kv_heads, head_dim)
        v = (x @ params["wv"]).reshape(B, S, kv_heads, head_dim)
        if positions is None:
            positions = jnp.arange(S)[None, :].repeat(B, 0)
        q = apply_rope(q, positions, rope_theta, rope_frac)
        k = apply_rope(k, positions, rope_theta, rope_frac)
    else:
        k, v = cross_kv
        mode = "full"

    if cp_axis is not None and cross_kv is None:
        # Ring-style context parallelism (inside shard_map): the
        # sequence axis of x/positions/segment_ids is sharded over
        # `cp_axis`; the segment table travels with each KV hop.
        from ..parallel.ring_attention import ring_attention
        o = ring_attention(q, k, v, positions, axis_name=cp_axis,
                           mode=mode, window=window,
                           q_seg=segment_ids, q_span=span_ids)
        out = o.reshape(B, S, n_heads * head_dim) @ params["wo"]
        return (out, (k, v)) if return_kv else out

    if impl == "pallas":
        if segment_ids is not None or span_ids is not None:
            from ..kernels.ops import flash_attention_packed
            seg = (segment_ids if segment_ids is not None
                   else jnp.zeros((B, S), jnp.int32))
            o = flash_attention_packed(q, k, v, seg, span_ids=span_ids,
                                       mode=mode, window=window)
        else:
            from ..kernels.ops import flash_attention
            o = flash_attention(q, k, v, mode=mode, window=window)
    elif impl == "reference":
        o = attn_reference(q, k, v, mode=mode, window=window,
                           segment_ids=segment_ids, span_ids=span_ids)
    elif (mode == "sliding" and cross_kv is None and impl == "chunked"
          and segment_ids is None and span_ids is None):
        o = attn_banded(q, k, v, window=window, chunk=min(attn_chunk, 512))
    else:
        o = attn_chunked(q, k, v, mode=mode, window=window,
                         chunk=attn_chunk, segment_ids=segment_ids,
                         span_ids=span_ids)
    out = o.reshape(B, S, n_heads * head_dim) @ params["wo"]
    if return_kv:
        return out, (k, v)
    return out


def project_qkv_decode(params, x1, *, n_heads, kv_heads, head_dim,
                       rope_theta, position, rope_frac: float = 1.0):
    """Projections for one decode token; position [B] absolute."""
    B = x1.shape[0]
    q = (x1 @ params["wq"]).reshape(B, 1, n_heads, head_dim)
    k = (x1 @ params["wk"]).reshape(B, 1, kv_heads, head_dim)
    v = (x1 @ params["wv"]).reshape(B, 1, kv_heads, head_dim)
    pos = position[:, None]
    q = apply_rope(q, pos, rope_theta, rope_frac)
    k = apply_rope(k, pos, rope_theta, rope_frac)
    return q, k, v
