"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence:  a_t = a^(c * r_t),  a = sigmoid(Lambda),  c = 8
             h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Linear first-order recurrences are associative, so the training/prefill
path uses `jax.lax.associative_scan` (log-depth — the TPU-native
replacement for the paper-cited CUDA linear-scan kernels), and decode is
the O(1) step. The full Griffin recurrent block wraps the RG-LRU with a
GeLU gate branch and a short causal conv, then projects back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init

_C = 8.0


def init_rglru_block(key, d_model: int, lru_width: int, conv_width: int,
                     dtype, n_blocks: int = 8) -> dict:
    """Gates use BLOCK-DIAGONAL weights (as in the RecurrentGemma
    reference implementation) — [nb, W/nb, W/nb]; the block axis is also
    the natural tensor-parallel shard axis."""
    while lru_width % n_blocks:
        n_blocks -= 1
    wb = lru_width // n_blocks
    ks = jax.random.split(key, 6)
    lam = jax.random.uniform(ks[4], (lru_width,), jnp.float32, 2.0, 5.0)
    blk = (jax.random.normal(ks[3], (2, n_blocks, wb, wb), jnp.float32)
           / jnp.sqrt(wb)).astype(dtype)
    return {
        "in_gate": dense_init(ks[0], d_model, lru_width, dtype),
        "in_rec": dense_init(ks[1], d_model, lru_width, dtype),
        "conv": (jax.random.normal(ks[2], (conv_width, lru_width),
                                   jnp.float32) * 0.1).astype(dtype),
        "w_a": blk[0],
        "w_x": blk[1],
        "b_a": jnp.zeros((lru_width,), jnp.float32),
        "b_x": jnp.zeros((lru_width,), jnp.float32),
        "lambda": lam,                       # a = sigmoid(lambda) in (0,1)
        "out": dense_init(jax.random.fold_in(key, 7), lru_width, d_model,
                          dtype),
    }


def _causal_conv(x, w):
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))


def _blockdiag(u, w):
    """u: [..., W], w: [nb, Wb, Wb] block-diagonal matmul."""
    nb, wb, _ = w.shape
    ub = u.reshape(*u.shape[:-1], nb, wb)
    out = jnp.einsum("...nw,nwv->...nv", ub, w)
    return out.reshape(*u.shape)


def _gates(params, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(_blockdiag(uf, params["w_a"].astype(jnp.float32))
                       + params["b_a"])
    i = jax.nn.sigmoid(_blockdiag(uf, params["w_x"].astype(jnp.float32))
                       + params["b_x"])
    log_a_base = jax.nn.log_sigmoid(params["lambda"])   # log a, a in (0,1)
    log_a = _C * r * log_a_base                         # a_t = a^(c r_t)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * (i * u.astype(jnp.float32))


def rglru_scan(params: dict, u: jax.Array,
               h0: jax.Array | None = None) -> jax.Array:
    """u: [B,S,W] -> h: [B,S,W] via parallel associative scan."""
    a, b = _gates(params, u)
    if h0 is not None:
        # fold the carried state into the first step's additive term
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(params: dict, x: jax.Array,
                h0: jax.Array | None = None, *, return_state: bool = False):
    """Griffin recurrent block: [B,S,D] -> [B,S,D]."""
    gate = jax.nn.gelu((x @ params["in_gate"]).astype(jnp.float32))
    u = x @ params["in_rec"]
    u = _causal_conv(u, params["conv"])
    h = rglru_scan(params, u, h0)
    y = (h * gate).astype(x.dtype) @ params["out"]
    if return_state:
        return y, h[:, -1]
    return y


def rglru_init_state(batch: int, lru_width: int, conv_width: int,
                     dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, lru_width), jnp.float32),
        "conv_buf": jnp.zeros((batch, conv_width - 1, lru_width), dtype),
    }


def rglru_decode_step(params: dict, x1: jax.Array, state: dict):
    """x1: [B,D] -> (y [B,D], new state). O(1)."""
    gate = jax.nn.gelu((x1 @ params["in_gate"]).astype(jnp.float32))
    u = x1 @ params["in_rec"]
    buf = jnp.concatenate([state["conv_buf"], u[:, None]], axis=1)
    u = jnp.einsum("bwc,wc->bc", buf, params["conv"])
    a, b = _gates(params, u[:, None])
    a, b = a[:, 0], b[:, 0]
    h = a * state["h"] + b
    y = (h * gate).astype(x1.dtype) @ params["out"]
    return y, {"h": h, "conv_buf": buf[:, 1:]}
