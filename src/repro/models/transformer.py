"""Transformer assembly: per-family blocks + scan-over-layers stacking.

Layer parameters are STACKED along a leading [L] axis and consumed with
`jax.lax.scan`, so the compiled HLO contains one layer's program
regardless of depth — essential for the 512-device dry-runs of a
126-layer model. `cfg.remat` wraps the block body in `jax.checkpoint`.

Families:
  dense  — [attn + MLP] x L                     (llama3/glm4/chatglm3/minitron/pixtral LM)
  moe    — [attn + MoE-FFN] x L                 (granite, olmoe)
  ssm    — [mamba2 SSD] x L                     (mamba2-370m)
  hybrid — [(rec, rec, attn) + MLP each] x ...  (recurrentgemma)
  audio  — whisper enc(full attn) + dec(causal + cross)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.act_sharding import constrain
from . import attention as attn_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .attention import attention, attn_decode, init_attention, \
    project_qkv_decode
from .layers import (_dtype, dense_init, embed, init_embedding, init_mlp,
                     init_rmsnorm, init_layernorm, layer_norm, mlp,
                     rms_norm, unembed)


# ==========================================================================
# Per-layer init (vmapped over layer keys -> stacked params)
# ==========================================================================
def _init_dense_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                               cfg.resolved_head_dim, dt),
        "ln2": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dt),
    }


def _init_moe_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                               cfg.resolved_head_dim, dt),
        "ln2": init_rmsnorm(cfg.d_model, dt),
        "moe": moe_mod.init_moe(k2, cfg.d_model, cfg.moe.n_experts,
                                cfg.moe.expert_ff, dt),
    }


def _init_ssm_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    s = cfg.ssm
    return {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "ssm": ssm_mod.init_ssm(key, cfg.d_model, d_state=s.d_state,
                                head_dim=s.head_dim, expand=s.expand,
                                conv_width=s.conv_width, dtype=dt),
    }


def _init_rec_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    h = cfg.hybrid
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dt),
        "rec": rglru_mod.init_rglru_block(
            k1, cfg.d_model, h.lru_width or cfg.d_model, h.conv_width, dt),
        "ln2": init_rmsnorm(cfg.d_model, dt),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.activation, dt),
    }


def _init_enc_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_layernorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                               cfg.resolved_head_dim, dt),
        "ln2": init_layernorm(cfg.d_model, dt),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, "gelu", dt),
    }


def _init_encdec_layer(key, cfg: ModelConfig):
    dt = _dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_layernorm(cfg.d_model, dt),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                               cfg.resolved_head_dim, dt),
        "ln_x": init_layernorm(cfg.d_model, dt),
        "xattn": init_attention(k2, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                                cfg.resolved_head_dim, dt),
        "ln2": init_layernorm(cfg.d_model, dt),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, "gelu", dt),
    }


_LAYER_INIT = {
    "dense": _init_dense_layer,
    "moe": _init_moe_layer,
    "ssm": _init_ssm_layer,
    "vlm": _init_dense_layer,
}


# ==========================================================================
# Block apply fns: (params_l, x, ctx) -> (x, aux)
# ==========================================================================
def _attn_kwargs(cfg: ModelConfig, mode: str, window=None):
    return dict(n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
                rope_frac=(0.0 if not cfg.use_rope
                           else 0.5 if cfg.rope_2d else 1.0),
                impl=cfg.attn_impl, mode=mode, window=window,
                cp_axis=cfg.cp_axis)


def _dense_block(p, x, cfg: ModelConfig, mode="causal", window=None,
                 positions=None, segment_ids=None, span_ids=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    x = x + attention(p["attn"], h, positions=positions,
                      segment_ids=segment_ids, span_ids=span_ids,
                      **_attn_kwargs(cfg, mode, window))
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.activation)
    return x, jnp.zeros((), jnp.float32)


def _moe_block(p, x, cfg: ModelConfig, mode="causal", window=None,
               positions=None, segment_ids=None, span_ids=None):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    x = x + attention(p["attn"], h, positions=positions,
                      segment_ids=segment_ids, span_ids=span_ids,
                      **_attn_kwargs(cfg, mode, window))
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    out, aux = moe_mod.moe_ffn(p["moe"], h, top_k=cfg.moe.top_k,
                               capacity_factor=cfg.moe.capacity_factor,
                               dispatch=cfg.moe.dispatch,
                               dispatch_group=cfg.moe.dispatch_group)
    return x + out, aux


def _ssm_block(p, x, cfg: ModelConfig, **_):
    s = cfg.ssm
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    x = x + ssm_mod.ssm_forward(
        p["ssm"], h, d_state=s.d_state, head_dim=s.head_dim,
        expand=s.expand, chunk=s.chunk,
        impl="pallas" if cfg.attn_impl == "pallas" else "jnp")
    return x, jnp.zeros((), jnp.float32)


def _rec_block(p, x, cfg: ModelConfig, **_):
    h = rms_norm(p["ln1"], x, cfg.norm_eps)
    x = x + rglru_mod.rglru_block(p["rec"], h)
    h = rms_norm(p["ln2"], x, cfg.norm_eps)
    x = x + mlp(p["mlp"], h, cfg.activation)
    return x, jnp.zeros((), jnp.float32)


_BLOCK = {"dense": _dense_block, "moe": _moe_block, "ssm": _ssm_block,
          "vlm": _dense_block}


# ==========================================================================
# Stacks
# ==========================================================================
def init_stack(key, cfg: ModelConfig, n_layers: int, init_fn):
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: init_fn(k, cfg))(keys)


def apply_stack(stacked, x, block_fn, remat: bool, scan: bool = True):
    """Scan x through stacked layer params, accumulating aux losses."""
    def body(carry, p_l):
        h, aux = carry
        h = constrain(h, "hidden")
        fn = jax.checkpoint(block_fn) if remat else block_fn
        h, a = fn(p_l, h)
        return (h, aux + a), None

    if scan:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   stacked)
    else:
        aux = jnp.zeros((), jnp.float32)
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        for i in range(n):
            p_l = jax.tree.map(lambda a: a[i], stacked)
            (x, aux), _ = body((x, aux), p_l)
    return x, aux


# ==========================================================================
# Hybrid (RecurrentGemma) layout helpers
# ==========================================================================
def hybrid_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    """(n_full_units, tail_block_types). 26 layers @ (rec,rec,attn) ->
    8 full units + ('rec','rec') tail."""
    unit = cfg.hybrid.pattern
    n_units = cfg.n_layers // len(unit)
    tail = cfg.n_layers - n_units * len(unit)
    return n_units, unit[:tail]
