"""Mamba-2 (SSD — state-space duality) block, arXiv:2405.21060.

Chunked SSD algorithm: within a chunk the recurrence is evaluated in its
"dual" quadratic attention-like form (matmuls — MXU friendly); states are
passed between chunks with an exact sequential scan over chunk summaries.
This is the TPU-native adaptation: chunk size is picked so the intra-chunk
matrices live in VMEM and hit the 128-lane MXU, while the O(S/chunk) scan
carries only the [H, P, N] state.

Scalar-identity A (Mamba-2's choice): a_t = exp(dt_t * A) per head.

Decode: h <- a * h + dt * B x ; y = C h + D x  (O(1) per token).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_ssm(key, d_model: int, *, d_state: int, head_dim: int,
             expand: int, conv_width: int, dtype) -> dict:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 5)
    return {
        # fused input projection -> [z (gate), x, B, C, dt]
        "in_proj": dense_init(
            ks[0], d_model,
            2 * d_inner + 2 * d_state + n_heads, dtype),
        "conv": (jax.random.normal(ks[1],
                                   (conv_width, d_inner + 2 * d_state),
                                   jnp.float32) * 0.1).astype(dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "out_proj": dense_init(ks[2], d_inner, d_model, dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }


def _split_proj(p, d_inner, d_state, n_heads):
    z, xbcdt = jnp.split(p, [d_inner], axis=-1)
    x, B, C, dt = jnp.split(
        xbcdt, [d_inner, d_inner + d_state, d_inner + 2 * d_state], axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w):
    """Depthwise causal conv: x [B,S,C], w [W,C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out


def ssm_forward(params: dict, xin: jax.Array, *, d_state: int,
                head_dim: int, expand: int, chunk: int,
                dt_min: float = 1e-3, impl: str = "jnp") -> jax.Array:
    """xin: [B,S,D] -> [B,S,D] (training/prefill path, chunked SSD).

    `impl="pallas"` routes the intra-chunk dual form through the
    kernels/ssd_chunk.py Pallas kernel (VMEM-resident [c,c] decay
    matrices); "jnp" is the portable per-head path below."""
    Bsz, S, Dm = xin.shape
    d_inner = expand * Dm
    H = d_inner // head_dim
    P, N = head_dim, d_state

    proj = xin @ params["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(proj, d_inner, d_state, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv"]))
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"]) + dt_min        # [B,S,H]
    A = -jnp.exp(params["A_log"])                             # [H] (<0)

    # pad to chunk multiple
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    xh = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, N).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H)

    if impl == "pallas":
        from ..kernels.ops import ssd_chunk_scan
        A = -jnp.exp(params["A_log"])
        G = Bsz * H
        rep = lambda t: jnp.broadcast_to(                    # noqa: E731
            t[:, None], (Bsz, H) + t.shape[1:]).reshape((G,) + t.shape[1:])
        xg = xh.transpose(0, 3, 1, 2, 4).reshape(G, nc, chunk, P)
        dtg = dtc.transpose(0, 3, 1, 2).reshape(G, nc, chunk)
        dag = dtg * jnp.tile(A, Bsz)[:, None, None]
        y = ssd_chunk_scan(rep(Cc), rep(Bc), xg, dag, dtg)   # [G,nc,c,P]
        y = y + xg * jnp.tile(params["D"], Bsz)[:, None, None, None]
        y = y.reshape(Bsz, H, Sp, P).transpose(0, 2, 1, 3)[:, :S]
        return _ssm_output(params, y, z, Bsz, S, d_inner, xin.dtype)

    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                # [B,nc,c,c]
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))

    def per_head(args):
        """SSD for ONE head — keeps the [c,c] decay matrices per-head so
        the peak live tensor is [B,nc,c,c], not [B,nc,c,c,H] (which at
        production shapes is hundreds of GB)."""
        xh_h, dtc_h, A_h, D_h = args   # [B,nc,c,P], [B,nc,c], [], []
        da = dtc_h * A_h
        cum = jnp.cumsum(da, axis=2)                          # [B,nc,c]
        seg_end = cum[:, :, -1]                               # [B,nc]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) * (i >= j)
        diff = cum[:, :, :, None] - cum[:, :, None, :]        # [B,nc,c,c]
        L = jnp.where(tril[None, None], jnp.exp(diff), 0.0)
        scores = cb * L * dtc_h[:, :, None, :]                # [B,nc,c,c]
        y_intra = jnp.einsum("bcij,bcjp->bcip", scores, xh_h)
        # chunk summaries -> inter-chunk recurrence
        decay_to_end = jnp.exp(seg_end[:, :, None] - cum)     # [B,nc,c]
        states = jnp.einsum("bcj,bcjn,bcjp->bcnp",
                            decay_to_end * dtc_h, Bc, xh_h)   # [B,nc,N,P]

        def scan_fn(h, inp):
            st, dec = inp
            return h * jnp.exp(dec)[:, None, None] + st, h    # emit PREV
        _, h_prev = jax.lax.scan(
            scan_fn, jnp.zeros((Bsz, N, P), jnp.float32),
            (states.transpose(1, 0, 2, 3), seg_end.transpose(1, 0)))
        h_prev = h_prev.transpose(1, 0, 2, 3)                 # [B,nc,N,P]
        y_inter = jnp.einsum("bcin,bcnp->bcip", Cc, h_prev) \
            * jnp.exp(cum)[..., None]
        return y_intra + y_inter + xh_h * D_h

    y = jax.lax.map(per_head,
                    (xh.transpose(3, 0, 1, 2, 4), dtc.transpose(3, 0, 1, 2),
                     A, params["D"]))                          # [H,B,nc,c,P]
    y = y.transpose(1, 2, 3, 0, 4).reshape(Bsz, Sp, H, P)[:, :S]
    return _ssm_output(params, y, z, Bsz, S, d_inner, xin.dtype)


def _ssm_output(params, y, z, Bsz, S, d_inner, out_dtype):
    """Gated RMSNorm (Mamba-2) + output projection."""
    y = y.reshape(Bsz, S, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(
        jnp.float32)
    return (y.astype(out_dtype)) @ params["out_proj"]


def ssm_init_state(batch: int, d_model: int, *, d_state: int,
                   head_dim: int, expand: int, conv_width: int,
                   dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    H = d_inner // head_dim
    return {
        "h": jnp.zeros((batch, H, d_state, head_dim), jnp.float32),
        "conv_buf": jnp.zeros((batch, conv_width - 1,
                               d_inner + 2 * d_state), dtype),
    }


def ssm_decode_step(params: dict, x1: jax.Array, state: dict, *,
                    d_state: int, head_dim: int, expand: int,
                    dt_min: float = 1e-3):
    """x1: [B,D] one token. Returns (y [B,D], new_state). O(1) per token."""
    Bsz, Dm = x1.shape
    d_inner = expand * Dm
    H = d_inner // head_dim
    proj = x1 @ params["in_proj"]
    z, x, Bm, Cm, dt = _split_proj(proj, d_inner, d_state, H)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                # [B,C]
    buf = jnp.concatenate([state["conv_buf"], xbc[:, None]], axis=1)
    w = params["conv"]
    conv_out = jnp.einsum("bwc,wc->bc", buf, w)
    xbc = jax.nn.silu(conv_out)
    new_buf = buf[:, 1:]
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"]) + dt_min
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A)                                        # [B,H]
    xh = x.reshape(Bsz, H, head_dim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bn,bhp->bhnp", dt, Bm.astype(jnp.float32), xh)
    h = state["h"] * a[..., None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(Bsz, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(
        jnp.float32)
    out = y.astype(x1.dtype) @ params["out_proj"]
    return out, {"h": h, "conv_buf": new_buf}
