"""Top-level model API.

  init_params(key, cfg)                        -> params pytree
  forward(params, cfg, batch)                  -> (logits [B,S,V], aux)
  init_cache(cfg, batch_size, cache_len)       -> decode cache pytree
  prefill_cache(params, cfg, batch, cache_len) -> cache  (audio cross-KV)
  decode_step(params, cfg, cache, tokens [B])  -> (logits [B,V], cache)

Batch dicts per family (all stub frontends produce *embeddings*):
  dense/moe/ssm/hybrid: {tokens}
  vlm:   {tokens, patch_embeds [B,P,Dv], patch_pos [B,P]}
  audio: {frames [B,F,D], tokens [B,S]}   (frames = conv-frontend stub)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..parallel.act_sharding import constrain
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import ssm as ssm_mod
from .attention import attn_decode, attention, init_attention, \
    project_qkv_decode
from .layers import (_dtype, dense_init, embed, init_embedding,
                     init_layernorm, init_mlp, init_rmsnorm, layer_norm,
                     mlp, rms_norm, unembed)
from .transformer import (_BLOCK, _LAYER_INIT, _init_enc_layer,
                          _init_encdec_layer, _init_rec_layer,
                          _attn_kwargs, apply_stack, hybrid_layout,
                          init_stack)


def sinusoidal(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.arange(0, dim, 2, jnp.float32)
                  * (jnp.log(10_000.0) / dim))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :dim]


def _sinusoidal_at(pos, dim: int) -> jax.Array:
    """One row of `sinusoidal` at a (traced) scalar position."""
    inv = jnp.exp(-jnp.arange(0, dim, 2, jnp.float32)
                  * (jnp.log(10_000.0) / dim))
    ang = jnp.asarray(pos, jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:dim]


# ==========================================================================
# Init
# ==========================================================================
def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg.param_dtype)
    k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(k_emb, cfg.vocab, cfg.d_model, dt),
        "ln_f": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)

    if cfg.family in ("dense", "moe", "ssm", "vlm"):
        params["layers"] = init_stack(
            k_layers, cfg, cfg.n_layers, _LAYER_INIT[cfg.family])
    elif cfg.family == "hybrid":
        n_units, tail = hybrid_layout(cfg)
        ku, kt = jax.random.split(k_layers)

        def init_unit(k):
            ks = jax.random.split(k, len(cfg.hybrid.pattern))
            unit = {}
            for i, kind in enumerate(cfg.hybrid.pattern):
                init = (_init_rec_layer if kind == "rec"
                        else _LAYER_INIT["dense"])
                unit[f"{i}_{kind}"] = init(ks[i], cfg)
            return unit

        params["units"] = jax.vmap(init_unit)(
            jax.random.split(ku, n_units))
        params["tail"] = {
            f"{i}_{kind}": (_init_rec_layer if kind == "rec"
                            else _LAYER_INIT["dense"])(
                jax.random.fold_in(kt, i), cfg)
            for i, kind in enumerate(tail)}
    elif cfg.family == "audio":
        ke, kd = jax.random.split(k_layers)
        params["enc_layers"] = init_stack(
            ke, cfg, cfg.encdec.n_enc_layers, _init_enc_layer)
        params["ln_enc"] = init_layernorm(cfg.d_model, dt)
        params["dec_layers"] = init_stack(
            kd, cfg, cfg.n_layers, _init_encdec_layer)
    else:
        raise ValueError(cfg.family)

    if cfg.family == "vlm":
        params["connector"] = dense_init(
            k_extra, cfg.vlm.vision_dim, cfg.d_model, dt)
    return params


# ==========================================================================
# Embedding assembly (modality interleave)
# ==========================================================================
def _input_embeddings(params, cfg: ModelConfig, batch) -> jax.Array:
    x = constrain(embed(params["embed"], batch["tokens"]), "hidden")
    if cfg.family == "vlm":
        proj = batch["patch_embeds"].astype(x.dtype) @ params["connector"]
        x = jax.vmap(lambda e, p, pos: e.at[pos].set(p))(
            x, proj, batch["patch_pos"])
    return x


def _head(params, cfg: ModelConfig, x) -> jax.Array:
    x = constrain(rms_norm(params["ln_f"], x, cfg.norm_eps), "prehead")
    if cfg.tie_embeddings:
        return constrain(unembed(params["embed"], x, tied=True), "logits")
    return constrain(unembed(params["head"], x, tied=False), "logits")


# ==========================================================================
# Forward (train / prefill)
# ==========================================================================
def forward(params, cfg: ModelConfig, batch,
            mode: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "audio":
        return _forward_audio(params, cfg, batch)
    x = _input_embeddings(params, cfg, batch)
    attn_mode = mode or ("sliding" if cfg.sliding_window else "causal")
    window = cfg.sliding_window
    positions = batch.get("positions")   # global positions (CP shards)
    # packed varlen: [B,S] segment table (-1 = tail padding); positions
    # reset per segment (core/packing.flatten_group produces both)
    segment_ids = batch.get("segment_ids")
    # mixed modality mask: [B,S] bidirectional-block table (-1 = causal
    # text / padding) — vision/audio spans attend forward within their
    # block (flatten_group / padded_batch produce it)
    span_ids = batch.get("modality_ids")

    if cfg.family in ("dense", "moe", "ssm", "vlm"):
        block = _BLOCK[cfg.family]
        def body(p_l, h):
            return block(p_l, h, cfg, mode=attn_mode, window=window,
                         positions=positions, segment_ids=segment_ids,
                         span_ids=span_ids)
        x, aux = apply_stack(params["layers"], x, body, cfg.remat,
                             cfg.scan_layers)
    elif cfg.family == "hybrid":
        x, aux = _hybrid_forward(params, cfg, x, positions, segment_ids,
                                 span_ids)
    else:
        raise ValueError(cfg.family)
    return _head(params, cfg, x), aux


def _hybrid_block(p_unit, x, cfg: ModelConfig, positions=None,
                  segment_ids=None, span_ids=None):
    from .transformer import _dense_block, _rec_block
    aux = jnp.zeros((), jnp.float32)
    for name in sorted(p_unit.keys()):
        kind = name.split("_")[1]
        if kind == "rec":
            x, a = _rec_block(p_unit[name], x, cfg)
        else:
            x, a = _dense_block(p_unit[name], x, cfg, mode="sliding",
                                window=cfg.hybrid.window,
                                positions=positions,
                                segment_ids=segment_ids,
                                span_ids=span_ids)
        aux = aux + a
    return x, aux


def _hybrid_forward(params, cfg: ModelConfig, x, positions=None,
                    segment_ids=None, span_ids=None):
    def body(p_unit, h):
        return _hybrid_block(p_unit, h, cfg, positions, segment_ids,
                             span_ids)
    x, aux = apply_stack(params["units"], x, body, cfg.remat,
                         cfg.scan_layers)
    x, a2 = _hybrid_block(params["tail"], x, cfg, positions, segment_ids,
                          span_ids)
    return x, aux + a2


def _forward_audio(params, cfg: ModelConfig, batch):
    from .transformer import _init_enc_layer  # noqa: F401
    frames = batch["frames"]
    B, F, _ = frames.shape
    enc = frames.astype(_dtype(cfg.param_dtype)) \
        + sinusoidal(F, cfg.d_model).astype(frames.dtype)

    def enc_block(p, h):
        g = layer_norm(p["ln1"], h, cfg.norm_eps)
        h = h + attention(p["attn"], g, **_attn_kwargs(cfg, "full"))
        g = layer_norm(p["ln2"], h, cfg.norm_eps)
        return h + mlp(p["mlp"], g, "gelu"), jnp.zeros((), jnp.float32)

    enc, _ = apply_stack(params["enc_layers"], enc, enc_block, cfg.remat,
                         cfg.scan_layers)
    enc = layer_norm(params["ln_enc"], enc, cfg.norm_eps)

    x = embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    x = x + sinusoidal(S, cfg.d_model).astype(x.dtype)

    hd = cfg.resolved_head_dim

    def dec_block(p, h):
        g = layer_norm(p["ln1"], h, cfg.norm_eps)
        h = h + attention(p["attn"], g, **_attn_kwargs(cfg, "causal"))
        g = layer_norm(p["ln_x"], h, cfg.norm_eps)
        ck = (enc @ p["xattn"]["wk"]).reshape(B, F, cfg.kv_heads, hd)
        cv = (enc @ p["xattn"]["wv"]).reshape(B, F, cfg.kv_heads, hd)
        h = h + attention(p["xattn"], g, cross_kv=(ck, cv),
                          **_attn_kwargs(cfg, "full"))
        g = layer_norm(p["ln2"], h, cfg.norm_eps)
        return h + mlp(p["mlp"], g, "gelu"), jnp.zeros((), jnp.float32)

    x, aux = apply_stack(params["dec_layers"], x, dec_block, cfg.remat,
                         cfg.scan_layers)
    return _head(params, cfg, x), aux


# ==========================================================================
# Serving prefill (dense/moe/vlm): last-token logits + filled KV cache
# ==========================================================================
def prefill(params, cfg: ModelConfig, batch, cache_len: int | None = None):
    """Returns (last_logits [B,1,V], cache). Sliding-window archs keep a
    ring buffer holding the final `window` positions; full-attention
    caches are padded to `cache_len` capacity (default S + 1 headroom is
    NOT added — pass the serving capacity). For ssm/hybrid/audio use
    forward() + init_cache (logits-only prefill; see DESIGN.md).
    """
    assert cfg.family in ("dense", "moe", "vlm"), cfg.family
    x = _input_embeddings(params, cfg, batch)
    B, S, _ = x.shape
    positions = batch.get("positions")
    mode = "sliding" if cfg.sliding_window else "causal"
    from .transformer import _attn_kwargs as AK
    from .attention import attention as attn_fn
    from .layers import mlp as mlp_fn

    def block_kv(p, h):
        g = rms_norm(p["ln1"], h, cfg.norm_eps)
        o, (k, v) = attn_fn(p["attn"], g, positions=positions,
                            return_kv=True,
                            **AK(cfg, mode, cfg.sliding_window))
        h = h + o
        g = rms_norm(p["ln2"], h, cfg.norm_eps)
        if cfg.family == "moe":
            out, _ = moe_mod.moe_ffn(p["moe"], g, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     dispatch=cfg.moe.dispatch,
                                     dispatch_group=cfg.moe.dispatch_group)
        else:
            out = mlp_fn(p["mlp"], g, cfg.activation)
        return h + out, (k, v)

    def body(h, p_l):
        h = constrain(h, "hidden")
        fn = jax.checkpoint(block_kv) if cfg.remat else block_kv
        h, kv = fn(p_l, h)
        return h, kv

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    logits = _head(params, cfg, x[:, -1:])

    W = cfg.sliding_window
    if W is not None and W < S:
        # keep last W positions, rotated so slot(p) = p % W
        ks = jnp.roll(ks[:, :, S - W:], (S - W) % W, axis=2)
        vs = jnp.roll(vs[:, :, S - W:], (S - W) % W, axis=2)
    elif cache_len is not None and cache_len > S:
        pad = ((0, 0), (0, 0), (0, cache_len - S), (0, 0), (0, 0))
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def prefill_chunk(params, cfg: ModelConfig, cache: Dict[str, Any],
                  tokens: jax.Array, start_pos, span_ids=None,
                  cache_span_ids=None) -> Dict[str, Any]:
    """Extend a full-attention KV cache by one prompt chunk.

    `tokens` [B, C] are prompt positions start_pos..start_pos+C-1;
    their K/V are written into cache rows [start_pos, start_pos+C) and
    each chunk token attends causally over everything written so far —
    the incremental step chunked prefill repeats until the prompt's KV
    is resident without ever materialising the O(L^2) one-shot prefill.

    `span_ids` [B,C] / `cache_span_ids` [B,T] (int32, -1 = causal)
    switch on the mixed modality mask: prompt tokens inside one
    bidirectional block (vision frame / audio window) attend each other
    regardless of order — exact when the serving scheduler keeps each
    block within one chunk (it snaps chunk boundaries to span ends).

    Requires a non-sliding cache (ring rotation would interleave chunk
    writes); dense/moe/vlm only. `start_pos` may be traced, so one
    compiled executable serves every chunk of every request at the same
    (B, C, T) bucket. Returns the updated cache with pos advanced by C
    (callers chunking a padded final bucket pass their own pos).
    """
    assert cfg.family in ("dense", "moe", "vlm"), cfg.family
    assert cfg.sliding_window is None, \
        "chunked prefill needs a non-rotating cache"
    from .attention import attn_prefill_chunk, project_qkv_decode  # noqa: F401
    from .layers import apply_rope

    x = _input_embeddings(params, cfg, {"tokens": tokens})
    B, C, _ = x.shape
    hd = cfg.resolved_head_dim
    rope_frac = (0.0 if not cfg.use_rope
                 else 0.5 if cfg.rope_2d else 1.0)
    positions = start_pos + jnp.arange(C)[None, :]     # [1,C] -> bcast B

    def block(p, h, ck, cv):
        # ck/cv: [B,T,Hkv,D] — one layer's cache rows
        g = rms_norm(p["ln1"], h, cfg.norm_eps)
        q = (g @ p["attn"]["wq"]).reshape(B, C, cfg.n_heads, hd)
        k = (g @ p["attn"]["wk"]).reshape(B, C, cfg.kv_heads, hd)
        v = (g @ p["attn"]["wv"]).reshape(B, C, cfg.kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta, rope_frac)
        k = apply_rope(k, positions, cfg.rope_theta, rope_frac)
        # drop-mode scatter, NOT dynamic_update_slice: a bucketed final
        # chunk may extend past the cache capacity, and the slice op
        # would clamp the start index and silently corrupt earlier rows
        rows = start_pos + jnp.arange(C)
        ck = ck.at[:, rows].set(k.astype(ck.dtype), mode="drop")
        cv = cv.at[:, rows].set(v.astype(cv.dtype), mode="drop")
        o = attn_prefill_chunk(q, ck, cv, start_pos,
                               chunk_span_ids=span_ids,
                               cache_span_ids=cache_span_ids)
        h = h + o.reshape(B, C, -1) @ p["attn"]["wo"]
        g = rms_norm(p["ln2"], h, cfg.norm_eps)
        if cfg.family == "moe":
            out, _ = moe_mod.moe_ffn(
                p["moe"], g, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor,
                dispatch=cfg.moe.dispatch,
                dispatch_group=cfg.moe.dispatch_group)
        else:
            out = mlp(p["mlp"], g, cfg.activation)
        return h + out, ck, cv

    def body(h, xs):
        p_l, ck, cv = xs
        h, ck, cv = block(p_l, h, ck, cv)
        return h, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    return {**cache, "k": ks, "v": vs,
            "pos": jnp.asarray(start_pos + C, jnp.int32)}


# ==========================================================================
# Decode caches
# ==========================================================================
def _kv_shape(cfg, n_layers, batch, cache_len):
    return (n_layers, batch, cache_len, cfg.kv_heads, cfg.resolved_head_dim)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None) -> Dict[str, Any]:
    """cache_len = context capacity; sliding-window archs allocate only
    min(window, cache_len) slots (ring buffer)."""
    dt = dtype or _dtype(cfg.param_dtype)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        T = min(cfg.sliding_window or cache_len, cache_len)
        cache["k"] = jnp.zeros(_kv_shape(cfg, cfg.n_layers, batch, T), dt)
        cache["v"] = jnp.zeros(_kv_shape(cfg, cfg.n_layers, batch, T), dt)
    elif cfg.family == "ssm":
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        H = d_inner // s.head_dim
        L = cfg.n_layers
        cache["h"] = jnp.zeros((L, batch, H, s.d_state, s.head_dim),
                               jnp.float32)
        cache["conv_buf"] = jnp.zeros(
            (L, batch, s.conv_width - 1, d_inner + 2 * s.d_state), dt)
    elif cfg.family == "hybrid":
        n_units, tail = hybrid_layout(cfg)
        h = cfg.hybrid
        W = h.lru_width or cfg.d_model
        n_rec_per_unit = sum(k == "rec" for k in h.pattern)
        n_attn_per_unit = sum(k == "attn" for k in h.pattern)
        T = min(h.window, cache_len)
        cache["rec_h"] = jnp.zeros((n_units, n_rec_per_unit, batch, W),
                                   jnp.float32)
        cache["rec_conv"] = jnp.zeros(
            (n_units, n_rec_per_unit, batch, h.conv_width - 1, W), dt)
        cache["k"] = jnp.zeros(
            (n_units, n_attn_per_unit, batch, T, cfg.kv_heads,
             cfg.resolved_head_dim), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
        n_rec_tail = sum(k == "rec" for k in tail)
        cache["tail_h"] = jnp.zeros((max(n_rec_tail, 1), batch, W),
                                    jnp.float32)
        cache["tail_conv"] = jnp.zeros(
            (max(n_rec_tail, 1), batch, h.conv_width - 1, W), dt)
    elif cfg.family == "audio":
        T = min(cfg.sliding_window or cache_len, cache_len)
        L = cfg.n_layers
        cache["k"] = jnp.zeros(_kv_shape(cfg, L, batch, T), dt)
        cache["v"] = jnp.zeros(_kv_shape(cfg, L, batch, T), dt)
        F = cfg.encdec.n_audio_frames
        cache["cross_k"] = jnp.zeros(_kv_shape(cfg, L, batch, F), dt)
        cache["cross_v"] = jnp.zeros(_kv_shape(cfg, L, batch, F), dt)
    else:
        raise ValueError(cfg.family)
    return cache


def prefill_cross_kv(params, cfg: ModelConfig, frames,
                     cache: Dict[str, Any]) -> Dict[str, Any]:
    """Audio: run the encoder once, fill per-layer cross K/V."""
    B, F, _ = frames.shape

    def enc_block(p, h):
        g = layer_norm(p["ln1"], h, cfg.norm_eps)
        h = h + attention(p["attn"], g, **_attn_kwargs(cfg, "full"))
        g = layer_norm(p["ln2"], h, cfg.norm_eps)
        return h + mlp(p["mlp"], g, "gelu"), jnp.zeros((), jnp.float32)

    enc = frames.astype(_dtype(cfg.param_dtype)) \
        + sinusoidal(F, cfg.d_model).astype(frames.dtype)
    enc, _ = apply_stack(params["enc_layers"], enc, enc_block, cfg.remat,
                         cfg.scan_layers)
    enc = layer_norm(params["ln_enc"], enc, cfg.norm_eps)
    hd = cfg.resolved_head_dim

    def per_layer(p):
        ck = (enc @ p["xattn"]["wk"]).reshape(B, F, cfg.kv_heads, hd)
        cv = (enc @ p["xattn"]["wv"]).reshape(B, F, cfg.kv_heads, hd)
        return ck, cv

    ck, cv = jax.vmap(per_layer)(params["dec_layers"])
    return {**cache, "cross_k": ck, "cross_v": cv}


# ==========================================================================
# Decode step
# ==========================================================================
def _write_kv(cache_k, cache_v, k1, v1, pos):
    """Ring-buffer write at slot pos % T. k1: [B,1,Hkv,D]."""
    T = cache_k.shape[1]
    slot = pos % T
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k1.astype(
        cache_k.dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v1.astype(
        cache_v.dtype), slot, axis=1)
    return ck, cv


def _dense_decode_layer(p, x1, ck, cv, pos, cfg: ModelConfig):
    B = x1.shape[0]
    h = rms_norm(p["ln1"], x1[:, None], cfg.norm_eps)[:, 0]
    q, k1, v1 = project_qkv_decode(
        p["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        position=jnp.full((B,), pos),
        rope_frac=0.5 if cfg.rope_2d else 1.0)
    ck, cv = _write_kv(ck, cv, k1, v1, pos)
    T = ck.shape[1]
    valid = jnp.minimum(pos + 1, T)
    o = attn_decode(q, ck, cv, jnp.full((B,), valid))
    x1 = x1 + (o.reshape(B, -1) @ p["attn"]["wo"])
    h = rms_norm(p["ln2"], x1[:, None], cfg.norm_eps)
    if cfg.family == "moe" or ("moe" in p):
        out, _ = moe_mod.moe_ffn(p["moe"], h, top_k=cfg.moe.top_k,
                                 capacity_factor=cfg.moe.capacity_factor,
                                 dispatch=cfg.moe.dispatch,
                                 dispatch_group=cfg.moe.dispatch_group)
    else:
        out = mlp(p["mlp"], h, cfg.activation)
    return x1 + out[:, 0], ck, cv


def decode_step(params, cfg: ModelConfig, cache: Dict[str, Any],
                tokens: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """tokens: [B] -> (logits [B,V], updated cache)."""
    pos = cache["pos"]
    x1 = embed(params["embed"], tokens)
    if cfg.family == "audio":
        x1 = x1 + _sinusoidal_at(pos, cfg.d_model).astype(x1.dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            p_l, ck, cv = xs
            x, ck, cv = _dense_decode_layer(p_l, x, ck, cv, pos, cfg)
            return x, (ck, cv)
        x1, (ck, cv) = jax.lax.scan(
            body, x1, (params["layers"], cache["k"], cache["v"]))
        cache = {**cache, "k": ck, "v": cv}
    elif cfg.family == "ssm":
        s = cfg.ssm
        def body(x, xs):
            p_l, h_l, cb_l = xs
            g = rms_norm(p_l["ln1"], x[:, None], cfg.norm_eps)[:, 0]
            y, st = ssm_mod.ssm_decode_step(
                p_l["ssm"], g, {"h": h_l, "conv_buf": cb_l},
                d_state=s.d_state, head_dim=s.head_dim, expand=s.expand)
            return x + y, (st["h"], st["conv_buf"])
        x1, (h, cb) = jax.lax.scan(
            body, x1, (params["layers"], cache["h"], cache["conv_buf"]))
        cache = {**cache, "h": h, "conv_buf": cb}
    elif cfg.family == "hybrid":
        x1, cache = _hybrid_decode(params, cfg, cache, x1, pos)
    elif cfg.family == "audio":
        def body(x, xs):
            p_l, ck, cv, xk, xv = xs
            B = x.shape[0]
            x, ck, cv = _audio_decode_self(p_l, x, ck, cv, pos, cfg)
            g = layer_norm(p_l["ln_x"], x[:, None], cfg.norm_eps)[:, 0]
            q = (g @ p_l["xattn"]["wq"]).reshape(
                B, 1, cfg.n_heads, cfg.resolved_head_dim)
            F = xk.shape[1]
            o = attn_decode(q, xk, xv, jnp.full((B,), F))
            x = x + o.reshape(B, -1) @ p_l["xattn"]["wo"]
            g = layer_norm(p_l["ln2"], x[:, None], cfg.norm_eps)
            x = x + mlp(p_l["mlp"], g, "gelu")[:, 0]
            return x, (ck, cv)
        x1, (ck, cv) = jax.lax.scan(
            body, x1, (params["dec_layers"], cache["k"], cache["v"],
                       cache["cross_k"], cache["cross_v"]))
        cache = {**cache, "k": ck, "v": cv}
    else:
        raise ValueError(cfg.family)

    logits = _head(params, cfg, x1[:, None])[:, 0]
    return logits, {**cache, "pos": pos + 1}


def _audio_decode_self(p, x1, ck, cv, pos, cfg: ModelConfig):
    B = x1.shape[0]
    h = layer_norm(p["ln1"], x1[:, None], cfg.norm_eps)[:, 0]
    q = (h @ p["attn"]["wq"]).reshape(B, 1, cfg.n_heads,
                                      cfg.resolved_head_dim)
    k1 = (h @ p["attn"]["wk"]).reshape(B, 1, cfg.kv_heads,
                                       cfg.resolved_head_dim)
    v1 = (h @ p["attn"]["wv"]).reshape(B, 1, cfg.kv_heads,
                                       cfg.resolved_head_dim)
    ck, cv = _write_kv(ck, cv, k1, v1, pos)
    T = ck.shape[1]
    valid = jnp.minimum(pos + 1, T)
    o = attn_decode(q, ck, cv, jnp.full((B,), valid))
    x1 = x1 + o.reshape(B, -1) @ p["attn"]["wo"]
    return x1, ck, cv


def _hybrid_decode(params, cfg: ModelConfig, cache, x1, pos):
    h_cfg = cfg.hybrid
    pattern = h_cfg.pattern
    rec_ids = [i for i, k in enumerate(pattern) if k == "rec"]
    attn_ids = [i for i, k in enumerate(pattern) if k == "attn"]

    def unit_body(x, xs):
        p_u, rh, rc, ck, cv = xs
        new_rh, new_rc, new_ck, new_cv = [], [], [], []
        ri = ai = 0
        for name in sorted(p_u.keys()):
            kind = name.split("_")[1]
            if kind == "rec":
                g = rms_norm(p_u[name]["ln1"], x[:, None], cfg.norm_eps)[:, 0]
                y, st = rglru_mod.rglru_decode_step(
                    p_u[name]["rec"], g,
                    {"h": rh[ri], "conv_buf": rc[ri]})
                x = x + y
                g = rms_norm(p_u[name]["ln2"], x[:, None], cfg.norm_eps)
                x = x + mlp(p_u[name]["mlp"], g, cfg.activation)[:, 0]
                new_rh.append(st["h"])
                new_rc.append(st["conv_buf"])
                ri += 1
            else:
                x, k_new, v_new = _dense_decode_layer(
                    p_u[name], x, ck[ai], cv[ai], pos, cfg)
                new_ck.append(k_new)
                new_cv.append(v_new)
                ai += 1
        return x, (jnp.stack(new_rh), jnp.stack(new_rc),
                   jnp.stack(new_ck), jnp.stack(new_cv))

    x1, (rh, rc, ck, cv) = jax.lax.scan(
        unit_body, x1,
        (params["units"], cache["rec_h"], cache["rec_conv"],
         cache["k"], cache["v"]))

    # tail (rec layers)
    th, tc = [], []
    ti = 0
    for name in sorted(params["tail"].keys()):
        kind = name.split("_")[1]
        p_l = params["tail"][name]
        if kind == "rec":
            g = rms_norm(p_l["ln1"], x1[:, None], cfg.norm_eps)[:, 0]
            y, st = rglru_mod.rglru_decode_step(
                p_l["rec"], g,
                {"h": cache["tail_h"][ti], "conv_buf": cache["tail_conv"][ti]})
            x1 = x1 + y
            g = rms_norm(p_l["ln2"], x1[:, None], cfg.norm_eps)
            x1 = x1 + mlp(p_l["mlp"], g, cfg.activation)[:, 0]
            th.append(st["h"])
            tc.append(st["conv_buf"])
            ti += 1
    new_cache = {**cache, "rec_h": rh, "rec_conv": rc, "k": ck, "v": cv}
    if th:
        new_cache["tail_h"] = jnp.stack(th)
        new_cache["tail_conv"] = jnp.stack(tc)
    return x1, new_cache
