"""First-class multimodal sequences (ISSUE 5).

Four layers of evidence that modality structure is now a real input,
not a derived scalar:

  * mask correctness — span-masked packed attention (Pallas kernel +
    block-diagonal reference + the differentiable chunked path) matches
    an independently constructed dense-mask oracle, forward and grad,
    across 1..8 segments with interleaved vision spans;
  * cost derivation — the span→eta derivation reproduces the scalar
    Eq. 8 path bit-for-bit when spans are synthesized from a target
    eta, and two sequences of EQUAL length but different span layouts
    get different costs/degrees;
  * plan IR — span-bearing plans JSON round-trip bit-identically (hash
    verified) for every registered planner, and the PlanCache keys
    modality mixes apart;
  * serving — requests carry spans, the scheduler never splits a
    bidirectional block across prefill chunks, and span-aware chunked
    prefill is invariant to the chunking.
"""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import (CostModel, ExecutionPlan, MMSequence,
                        ModalitySpan, SeqInfo, analytic_coeffs,
                        evaluate_degrees, sample_mm_batch, slice_spans,
                        spans_eta, synthesize_spans)
from repro.core.packing import flatten_group
from repro.kernels.flash_attention import flash_attention_packed_flat
from repro.kernels.ref import flash_attention_packed_ref
from repro.models.attention import attn_chunked, attn_reference

KEY = jax.random.PRNGKey(0)
NEG_INF = -1e30

CM = CostModel(dataclasses.replace(
    analytic_coeffs(hidden=1024, n_layers=8, n_heads=8, kv_heads=4,
                    ffn=4096, vocab=32000),
    m_ms=0.0, m_token=1.0))


# ------------------------------------------------------------ helpers
def _interleaved_layout(lens, vis_frac=0.5, frame=8):
    """seg/span tables + per-seq spans for packed buffers: each segment
    gets bidirectional vision frames of `frame` tokens interleaved with
    causal text, ~vis_frac of its tokens vision."""
    S = sum(lens)
    seg = np.full(S, -1, np.int32)
    span = np.full(S, -1, np.int32)
    spans_per_seq = []
    off, sid = 0, 0
    for i, L in enumerate(lens):
        seg[off:off + L] = i
        spans = []
        p = 0
        vis_left = int(L * vis_frac)
        while p < L:
            t = min(max(1, frame // 2), L - p)       # text block
            spans.append(ModalitySpan("text", p, t))
            p += t
            if vis_left > 0 and p < L:
                f = min(frame, vis_left, L - p)
                spans.append(ModalitySpan("vision", p, f,
                                          "bidirectional"))
                span[off + p:off + p + f] = sid
                sid += 1
                vis_left -= f
                p += f
        spans_per_seq.append(tuple(spans))
        off += L
    return seg, span, spans_per_seq


def _dense_oracle(q, k, v, seg, span):
    """Independent dense-mask oracle in float64 numpy: causal within a
    segment, OR same-bidirectional-block, rows without keys -> 0."""
    BH, S, D = q.shape
    s = np.einsum("bqd,bkd->bqk", np.asarray(q, np.float64),
                  np.asarray(k, np.float64)) / math.sqrt(D)
    seg = np.asarray(seg)
    span = np.asarray(span)
    same = (seg[:, None] == seg[None, :]) & (seg[:, None] >= 0)
    ok = np.tril(np.ones((S, S), bool))
    ok |= (span[:, None] >= 0) & (span[:, None] == span[None, :])
    m = same & ok
    s = np.where(m[None], s, NEG_INF)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bqk,bkd->bqd", p, np.asarray(v, np.float64))
    return np.where(m.any(-1)[None, :, None], o, 0.0)


SEGMENT_SETS = [
    [64],                                # 1 segment
    [37, 27],
    [5, 60, 3],
    [17, 9, 29, 13],
    [9, 9, 9, 9, 9, 9, 9, 9],            # 8 equal
    [31, 6, 19, 7, 11, 23, 5, 24],       # 8 uneven
]


# ---------------------------------------------------- kernel acceptance
@pytest.mark.parametrize("lens", SEGMENT_SETS,
                         ids=[f"{len(s)}seg" for s in SEGMENT_SETS])
def test_span_masked_packed_kernels_match_dense_oracle(lens):
    """Acceptance: Pallas packed kernel + block-diagonal reference with
    interleaved vision spans match the dense-mask oracle, atol 1e-4,
    including tail padding (exact zeros)."""
    seg, span, _ = _interleaved_layout(lens)
    S = sum(lens) + 11                    # tail padding
    segp = np.full(S, -1, np.int32)
    spanp = np.full(S, -1, np.int32)
    segp[:sum(lens)] = seg
    spanp[:sum(lens)] = span
    q = jax.random.normal(KEY, (3, S, 32))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (3, S, 32))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (3, S, 32))
    oracle = _dense_oracle(q, k, v, segp, spanp)
    out = flash_attention_packed_flat(
        q, k, v, jnp.asarray(segp), span_ids=jnp.asarray(spanp),
        block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), oracle,
                               atol=1e-4, rtol=1e-4)
    ref = flash_attention_packed_ref(q, k, v, jnp.asarray(segp),
                                     span_ids=jnp.asarray(spanp))
    np.testing.assert_allclose(np.asarray(ref), oracle,
                               atol=1e-4, rtol=1e-4)
    # the mixed mask is real: dropping the span table changes vision rows
    causal = flash_attention_packed_flat(
        q, k, v, jnp.asarray(segp), block_q=32, block_k=32)
    assert float(jnp.abs(out - causal).max()) > 1e-3


@pytest.mark.parametrize("lens", [[64], [37, 27], [17, 9, 29, 13]],
                         ids=["1seg", "2seg", "4seg"])
def test_span_masked_grads_match_dense_oracle(lens):
    """Acceptance: the differentiable (custom-VJP) chunked path used by
    the executor matches the dense-mask oracle forward AND grad with
    interleaved vision spans (valid region; padding rows are loss-masked
    by construction)."""
    seg, span, _ = _interleaved_layout(lens)
    valid = sum(lens)
    S = valid + 13
    segp = np.full(S, -1, np.int32)
    spanp = np.full(S, -1, np.int32)
    segp[:valid] = seg
    spanp[:valid] = span
    B, H, Hkv, D = 1, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, Hkv, D))
    segj = jnp.asarray(segp)[None]
    spanj = jnp.asarray(spanp)[None]

    def dense(q, k, v):
        """dense-mask oracle, differentiable (GQA expanded)."""
        kf = jnp.repeat(k, H // Hkv, axis=2).astype(jnp.float32)
        vf = jnp.repeat(v, H // Hkv, axis=2).astype(jnp.float32)
        s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                       kf.transpose(0, 1, 2, 3)) / math.sqrt(D)
        same = (segj[:, :, None] == segj[:, None, :]) \
            & (segj >= 0)[:, :, None]
        ok = jnp.tril(jnp.ones((S, S), bool))[None]
        ok = ok | ((spanj[:, :, None] >= 0)
                   & (spanj[:, :, None] == spanj[:, None, :]))
        m = same & ok
        s = jnp.where(m[:, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhst,bthd->bshd", p, vf)
        return jnp.where(m.any(-1)[:, :, None, None], o, 0.0)

    out = attn_chunked(q, k, v, mode="causal", chunk=32,
                       segment_ids=segj, span_ids=spanj)
    # q is [B,S,H,D]; dense expects the same layout via einsum over h
    den = dense(q.transpose(0, 1, 2, 3), k, v)
    np.testing.assert_allclose(np.asarray(out[:, :valid]),
                               np.asarray(den[:, :valid]),
                               atol=1e-4, rtol=1e-4)
    g = jax.grad(lambda a, b, c: (attn_chunked(
        a, b, c, mode="causal", chunk=32, segment_ids=segj,
        span_ids=spanj)[:, :valid] ** 2).sum(), argnums=(0, 1, 2))(
        q, k, v)
    gr = jax.grad(lambda a, b, c: (
        dense(a, b, c)[:, :valid] ** 2).sum(), argnums=(0, 1, 2))(
        q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_attn_reference_span_equals_dense_oracle():
    seg, span, _ = _interleaved_layout([24, 40])
    S = 64
    B, H, Hkv, D = 2, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, Hkv, D))
    out = attn_reference(q, k, v, mode="causal",
                         segment_ids=jnp.asarray(seg)[None],
                         span_ids=jnp.asarray(span)[None])
    kf = jnp.repeat(k, 2, 2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = jnp.repeat(v, 2, 2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    oracle = _dense_oracle(qf, kf, vf, seg, span)
    got = np.asarray(out.transpose(0, 2, 1, 3).reshape(B * H, S, D))
    np.testing.assert_allclose(got, oracle, atol=1e-4, rtol=1e-4)


def test_ring_span_table_rides_hops(subproc):
    """Mixed-mask ring CP: the modality table travels with every
    ppermute hop (alongside positions + segment ids), so a packed
    span-bearing buffer sharded over cp=3 matches the single-device
    reference, forward and grad."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.parallel.ring_attention import ring_attention
from repro.models.attention import attn_reference

devs = jax.devices()
mesh = Mesh(np.array(devs[:3]), ("cp",))
B,H,Hkv,Dh = 1, 4, 2, 16
lens = [25, 40, 14, 17]         # 96 tokens = 3 shards x 32
S = 96
seg = np.full(S, -1, np.int32); pos = np.zeros(S, np.int32)
span = np.full(S, -1, np.int32)
off = 0; sid = 0
for i, L in enumerate(lens):
    seg[off:off+L] = i; pos[off:off+L] = np.arange(L)
    # one vision block in the middle of each sequence (crosses shard
    # boundaries for the longer ones)
    a, b = off + L//4, off + 3*L//4
    span[a:b] = sid; sid += 1
    off += L
key = jax.random.PRNGKey(0)
q = jax.random.normal(key,(B,S,H,Dh))
k = jax.random.normal(jax.random.fold_in(key,1),(B,S,Hkv,Dh))
v = jax.random.normal(jax.random.fold_in(key,2),(B,S,Hkv,Dh))
posj = jnp.asarray(pos)[None]
segj = jnp.asarray(seg)[None]
spanj = jnp.asarray(span)[None]
fm = shard_map(
    lambda q,k,v,p,s,sp: ring_attention(q,k,v,p,axis_name="cp",
                                        q_seg=s,q_span=sp),
    mesh=mesh, in_specs=(P(None,"cp"),)*6, out_specs=P(None,"cp"))
out = fm(q,k,v,posj,segj,spanj)
ref = attn_reference(q,k,v,mode="causal",segment_ids=segj,
                     span_ids=spanj)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=3e-5, rtol=3e-5)
g = jax.grad(lambda q,k,v: (fm(q,k,v,posj,segj,spanj)**2).sum(),
             argnums=(0,1,2))(q,k,v)
gr = jax.grad(lambda q,k,v: (attn_reference(
    q,k,v,mode="causal",segment_ids=segj,span_ids=spanj)**2).sum(),
             argnums=(0,1,2))(q,k,v)
for a,b in zip(g,gr):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-4, rtol=5e-4)
print("ring span ok")
""", n_devices=3)


# -------------------------------------------------------- eta derivation
def test_spans_eta_anchors():
    full = (ModalitySpan("vision", 0, 100, "bidirectional"),)
    assert spans_eta(full) == 1.0
    text = (ModalitySpan("text", 0, 100),)
    assert spans_eta(text) == 0.0
    # splitting a block lowers eta: structure matters, not just counts
    one = (ModalitySpan("vision", 0, 64, "bidirectional"),
           ModalitySpan("text", 64, 64),)
    two = (ModalitySpan("vision", 0, 32, "bidirectional"),
           ModalitySpan("text", 32, 32),
           ModalitySpan("vision", 64, 32, "bidirectional"),
           ModalitySpan("text", 96, 32),)
    assert spans_eta(one) > spans_eta(two) > 0.0


@settings(max_examples=60, deadline=None)
@given(st.integers(8, 4096), st.floats(0.0, 1.0), st.integers(1, 9))
def test_span_eta_matches_scalar_group_time(length, frac, degree):
    """Property (satellite): a span layout synthesized from a target
    eta reproduces the SCALAR cost path exactly — group_time equal
    within 1e-9 relative, across degrees."""
    v = int(round(math.sqrt(frac) * length))
    eta = v * v / float(length) ** 2          # representable target
    spans = synthesize_spans(length, eta)
    structural = SeqInfo(length=0, seq_id=0, spans=spans)
    scalar = SeqInfo(length=length, eta=eta, seq_id=0)
    assert structural.length == length
    assert structural.eta == pytest.approx(eta, abs=1e-15)
    t_structural = CM.group_time([structural], degree)
    t_scalar = CM.group_time([scalar], degree)
    assert t_structural == pytest.approx(t_scalar, rel=1e-9)


def test_mmsequence_seqinfo_view_and_validation():
    mm = MMSequence(spans=(ModalitySpan("text", 0, 10),
                           ModalitySpan("vision", 10, 20,
                                        "bidirectional")), seq_id=5)
    si = mm.seq_info
    assert si.length == mm.length == 30
    assert si.eta == mm.eta == pytest.approx(400 / 900)
    assert si.seq_id == 5 and si.spans == mm.spans
    assert mm.modality_tokens() == {"text": 10, "vision": 20}
    with pytest.raises(ValueError):        # gap in the tiling
        MMSequence(spans=(ModalitySpan("text", 0, 10),
                          ModalitySpan("vision", 12, 8)))
    with pytest.raises(ValueError):        # bogus attn kind
        ModalitySpan("vision", 0, 4, attn="fancy")
    # slicing re-bases and clips
    assert slice_spans(mm.spans, 5, 10) == (
        ModalitySpan("text", 0, 5), ModalitySpan("vision", 5, 5,
                                                 "bidirectional"))


def test_seqinfo_legacy_construction_unchanged():
    s = SeqInfo(2048, 0.7, 3)
    assert (s.length, s.eta, s.seq_id, s.spans) == (2048, 0.7, 3, None)
    assert s.attn_weight == pytest.approx(1.7 * 2048 ** 2)


# -------------------------------------------- planner cost sensitivity
def _layout_pair(length=16384):
    """Two sequences of EQUAL length whose span layouts differ: one
    monolithic vision block vs the same vision budget split into many
    frames. Derived eta (and hence Eq. 8 cost) must differ."""
    vis = length * 3 // 4
    mono = SeqInfo(length=0, seq_id=0, spans=(
        ModalitySpan("vision", 0, vis, "bidirectional"),
        ModalitySpan("text", vis, length - vis)))
    frames = []
    off = 0
    frame = vis // 16
    for _ in range(16):
        frames.append(ModalitySpan("vision", off, frame,
                                   "bidirectional"))
        off += frame
    frames.append(ModalitySpan("text", off, length - off))
    split = SeqInfo(length=0, seq_id=0, spans=tuple(frames))
    assert mono.length == split.length == length
    assert mono.eta > split.eta
    return mono, split


def test_mixed_modality_changes_evaluate_degrees_and_chosen_degrees():
    """Satellite: same length, different span layout -> different
    derived eta -> different evaluated cost AND different chosen CP
    degrees when the allocator splits one rank pool between them."""
    from repro.core import DHPScheduler
    mono, split = _layout_pair()
    ev_mono = evaluate_degrees([[mono]], [4], CM.group_time)
    ev_split = evaluate_degrees([[split]], [4], CM.group_time)
    assert ev_mono.makespan > ev_split.makespan
    # both sequences in ONE wave on 16 ranks: the min-makespan DP must
    # give the monolithic-vision (higher derived eta) sequence MORE
    # ranks than the frame-split one of identical length
    heavy = CostModel(dataclasses.replace(
        CM.coeffs, a1=CM.coeffs.a1 * 50))
    batch = [dataclasses.replace(mono, seq_id=0),
             dataclasses.replace(split, seq_id=1)]
    budget = mono.length * 0.6          # one atomic group per sequence
    plan = DHPScheduler(heavy, 16, budget, balance_packing=False,
                        serial_fallback=False).schedule(batch)
    degree = {i: g.degree for mb in plan.micro_batches
              for g in mb.groups for i in g.seq_ids}
    assert degree[0] > degree[1], degree


def test_oracle_plan_cost_sees_span_structure():
    """Satellite: the oracle's plan_cost (analytic fallback before any
    measurements land) prices span layouts apart for equal lengths."""
    from repro.api import get_strategy
    mono, split = _layout_pair()
    strat = get_strategy("oracle").bind(CM, 8, float(mono.length))
    plan = strat.plan([mono])
    assert strat.plan_cost(plan, [mono]) > strat.plan_cost(plan, [split])


def test_plan_cache_distinguishes_modality_mixes():
    from repro.core import PlanCache
    mono, split = _layout_pair(4096)
    cache = PlanCache()
    assert cache.key([mono]) != cache.key([split])
    # scalar SeqInfos keep the legacy key space (no span signature)
    a = SeqInfo(4096, 0.5, 0)
    b = SeqInfo(4096, 0.5, 1)
    assert cache.key([a]) == cache.key([b])


# ------------------------------------------------------------ plan IR
PLANNERS = ("static", "megatron", "deepspeed", "dhp", "dhp-faithful",
            "bruteforce")


def _mm_batch(seed, n=6):
    rng = np.random.default_rng(seed)
    return sample_mm_batch("openvid", n, rng, max_tokens=2000,
                           tokens_per_frame=32)


@pytest.mark.parametrize("name", PLANNERS)
def test_plan_ir_round_trips_spans_bit_identically(name):
    """Satellite: span-bearing plans JSON round-trip with hash
    verification for every registered planner; spans survive exactly."""
    from repro.api import get_strategy
    mms = _mm_batch(3)
    strat = get_strategy(name, plan_cache=False).bind(CM, 8, 3000.0)
    plan = strat.plan(mms)
    assert plan.seq_spans and set(plan.seq_spans) == \
        {m.seq_id for m in mms}
    obj = json.loads(json.dumps(plan.to_json()))   # through real JSON
    back = ExecutionPlan.from_json(obj)            # verifies the hash
    assert back.seq_spans == plan.seq_spans
    assert back.structural_hash() == plan.structural_hash()
    # tampering with the span table must break the hash
    bad = plan.to_json()
    key = next(iter(bad["seq_spans"]))
    bad["seq_spans"][key][0][2] += 1
    with pytest.raises(ValueError, match="hash mismatch"):
        ExecutionPlan.from_json(bad)


def test_spanless_plans_hash_like_v2():
    """A plan without spans keeps the exact pre-span hash blob, so
    traces saved by the v2 IR still verify."""
    import hashlib
    from repro.api import get_strategy
    seqs = [SeqInfo(length=n, seq_id=i)
            for i, n in enumerate((128, 700, 1900))]
    plan = get_strategy("dhp", plan_cache=False).bind(
        CM, 8, 3000.0).plan(seqs)
    assert plan.seq_spans is None
    tree = [[[list(g.seq_ids), g.degree] for g in mb.groups]
            for mb in plan.micro_batches]
    want = hashlib.sha256(json.dumps(
        tree, separators=(",", ":")).encode()).hexdigest()[:16]
    assert plan.structural_hash() == want


def test_replay_preserves_recorded_plan_spans_and_hash():
    """A recorded plan's span table (or its absence) is part of the
    hash the trace was saved with — replay must NOT re-derive it from
    the incoming batch."""
    from repro.api import ReplayStrategy, get_strategy
    mms = _mm_batch(9)
    strat = get_strategy("dhp", plan_cache=False).bind(CM, 8, 3000.0)
    recorded = strat.plan(mms)
    want = recorded.structural_hash()
    # span-bearing plan replayed -> identical hash and spans
    rs = ReplayStrategy(plans=[ExecutionPlan.from_json(
        recorded.to_json())]).bind(CM, 8, 3000.0)
    replayed = rs.plan(mms)
    assert replayed.structural_hash() == want
    assert replayed.seq_spans == recorded.seq_spans
    # a v2-style SPAN-FREE recorded plan replayed against a span-bearing
    # stream keeps hashing like v2 (spans are not grafted on)
    bare = ExecutionPlan.from_json(recorded.to_json())
    bare.seq_spans = None
    v2_hash = bare.structural_hash()
    rs2 = ReplayStrategy(plans=[bare]).bind(CM, 8, 3000.0)
    replayed2 = rs2.plan(mms)
    assert replayed2.seq_spans is None
    assert replayed2.structural_hash() == v2_hash


def test_executor_causal_batches_keep_pre_span_executables():
    """Scalar (span-free) batches must compile the exact pre-span
    executable keys and ship no modality table — the span machinery is
    pay-for-what-you-use."""
    from repro.configs import get_config
    from repro.core import DHPScheduler
    from repro.core.executor import DHPExecutor
    from repro.data.pipeline import RaggedBatch
    from repro.models.model import init_params
    cfg = get_config("internvl3-2b").reduced().with_(family="dense",
                                                     vlm=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    infos = [SeqInfo(length=n, seq_id=i)
             for i, n in enumerate((90, 60, 40))]
    data = RaggedBatch(infos=infos, tokens=[
        rng.integers(0, cfg.vocab, size=s.length).astype(np.int32)
        for s in infos])
    cm = CostModel(dataclasses.replace(CM.coeffs))
    plan = DHPScheduler(cm, 1, mem_budget=400.0).schedule(infos)
    ex = DHPExecutor(cfg, packed=True)
    ex.run_plan(params, plan, data)
    assert ex.last_exe_keys
    for key in ex.last_exe_keys:
        assert key[0] == "pgrad" and "mm" not in key, key


def test_strategy_plan_accepts_mmsequences_directly():
    from repro.api import get_strategy
    mms = _mm_batch(7)
    infos = [m.seq_info for m in mms]
    s1 = get_strategy("dhp", plan_cache=False).bind(CM, 8, 3000.0)
    s2 = get_strategy("dhp", plan_cache=False).bind(CM, 8, 3000.0)
    p1, p2 = s1.plan(mms), s2.plan(infos)
    assert p1.structural_hash() == p2.structural_hash()


# ------------------------------------------------------------ packing
def test_flatten_group_modality_table():
    seqs = [np.arange(6, dtype=np.int32),
            np.arange(5, dtype=np.int32) + 50]
    spans = [
        (ModalitySpan("text", 0, 2),
         ModalitySpan("vision", 2, 3, "bidirectional"),
         ModalitySpan("text", 5, 1)),
        (ModalitySpan("audio", 0, 4, "bidirectional"),
         ModalitySpan("text", 4, 1)),
    ]
    batch, cu = flatten_group(seqs, bucket=16, spans=spans)
    mod = batch["modality_ids"][0]
    np.testing.assert_array_equal(
        mod[:11], [-1, -1, 0, 0, 0, -1, 1, 1, 1, 1, -1])
    assert (mod[11:] == -1).all()
    # distinct blocks got distinct ids (no cross-block bleed)
    assert mod[2] != mod[6]
    # spans omitted (or all None) -> NO modality table: pure-causal
    # batches keep the exact pre-span batch dict and attention path
    batch2, _ = flatten_group(seqs, bucket=16)
    assert "modality_ids" not in batch2
    batch3, _ = flatten_group(seqs, bucket=16, spans=[None, None])
    assert "modality_ids" not in batch3


def test_executor_modality_tokens_and_mixed_mask_parity(subproc):
    """End to end on 8 devices: a span-bearing loader batch executes
    with the mixed mask on BOTH executor paths (packed and padded) with
    equal loss/grads, and StepMetrics reports per-modality tokens."""
    subproc("""
import dataclasses, jax, numpy as np
from repro.api import ClusterSpec, Engine
from repro.configs import get_config
from repro.core import CostModel, DHPScheduler, analytic_coeffs
from repro.core.executor import DHPExecutor
from repro.data.pipeline import HeterogeneousLoader
from repro.models.model import init_params

cfg = get_config("internvl3-2b").reduced().with_(family="dense", vlm=None)
params = init_params(jax.random.PRNGKey(0), cfg)
loader = HeterogeneousLoader("openvid", 12, cfg.vocab, seed=1,
                             max_tokens=512, tokens_per_frame=16)
data = next(iter(loader))
assert all(s.spans for s in data.infos)
coeffs = dataclasses.replace(
    analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                    n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    ffn=cfg.d_ff, vocab=cfg.vocab), m_ms=0.0, m_token=1.0)
plan = DHPScheduler(CostModel(coeffs), 8, mem_budget=900.0).schedule(
    data.infos)
ex_p = DHPExecutor(cfg, packed=True)
ex_u = DHPExecutor(cfg, packed=False)
l_p, g_p = ex_p.run_plan(params, plan, data)
l_u, g_u = ex_u.run_plan(params, plan, data)
assert abs(float(l_p) - float(l_u)) < 2e-5, (float(l_p), float(l_u))
err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
          for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_u)))
assert err < 1e-4, err

# the mask is REAL: stripping the spans changes the loss
stripped = dataclasses.replace(data, infos=[
    dataclasses.replace(s, spans=None) for s in data.infos])
l_c, _ = ex_p.run_plan(params, plan, stripped)
assert abs(float(l_p) - float(l_c)) > 1e-6, (float(l_p), float(l_c))

# engine-level telemetry
eng = Engine(cfg, ClusterSpec.auto(mem_budget=900.0), strategy="dhp",
             seed=0)
hist = eng.train(steps=1, dataset="openvid", global_batch=6,
                 max_tokens=256, tokens_per_frame=16)
mt = hist[0].modality_tokens
assert mt.get("vision", 0) > 0 and mt.get("text", 0) > 0
assert sum(mt.values()) == hist[0].tokens
print("mixed-mask parity ok", err, mt)
""", n_devices=8)


# ------------------------------------------------------------ serving
def test_serving_scheduler_never_splits_bidirectional_blocks():
    from repro.api import demo_cost_model, get_strategy
    from repro.configs import get_config
    from repro.serving.kv_cache import KVCacheManager
    from repro.serving.scheduler import (ContinuousBatchingScheduler,
                                         ServeRequest)
    cfg = get_config("internvl3-2b").reduced()
    planner = get_strategy("dhp").bind(demo_cost_model(cfg), 1, 4096.0)
    kv = KVCacheManager(2, 64, 16)
    sched = ContinuousBatchingScheduler(kv, planner, prefill_chunk=16)
    spans = (ModalitySpan("text", 0, 10),
             ModalitySpan("vision", 10, 30, "bidirectional"),
             ModalitySpan("text", 40, 25))
    req = ServeRequest(request_id=0,
                       tokens=np.arange(65, dtype=np.int32),
                       max_new_tokens=4, spans=spans)
    sched.submit(req)
    seen = []
    while any(s.status == "prefill" for s in sched.states.values()) \
            or sched.queue:
        it = sched.step()
        for g in it.prefill_groups:
            for c in g.chunks:
                seen.append((c.start, c.length))
                sched.mark_prefilled(c.request_id, c.length)
    # every bidirectional block fully inside one chunk
    for start, length in seen:
        end = start + length
        assert not (10 < end < 40) or end >= 40, seen
    assert sum(ln for _, ln in seen) == req.prompt_len - 1
    # chunk SeqInfos derived their eta from the chunk's own spans: the
    # plan carried span tables
    assert sched.plans_validated >= 1


def test_span_aware_chunked_prefill_invariant_to_chunking():
    """Serving acceptance: span-aware chunked prefill produces the SAME
    KV cache whatever the chunking (chunks snapped to span boundaries),
    and a DIFFERENT cache than causal-only prefill — the vision block
    is really masked."""
    from repro.configs import get_config
    from repro.models.model import init_cache, init_params, prefill_chunk
    cfg = get_config("internvl3-2b").reduced().with_(
        family="dense", vlm=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    L, T = 48, 64
    toks = rng.integers(0, cfg.vocab, size=(1, L)).astype(np.int32)
    spans = (ModalitySpan("text", 0, 8),
             ModalitySpan("vision", 8, 24, "bidirectional"),
             ModalitySpan("text", 32, 16))
    row = np.full((1, T), -1, np.int32)
    row[0, 8:32] = 0

    def run(chunking):
        cache = init_cache(cfg, 1, T)
        for s, c in chunking:
            cs = np.full((1, c), -1, np.int32)
            cs[0] = row[0, s:s + c]
            cache = prefill_chunk(
                params, cfg, cache, jnp.asarray(toks[:, s:s + c]), s,
                span_ids=jnp.asarray(cs),
                cache_span_ids=jnp.asarray(row))
        return cache

    one = run([(0, 48)])
    # chunk boundaries at 8 and 32 = span boundaries (scheduler snap)
    many = run([(0, 8), (8, 24), (32, 16)])
    np.testing.assert_allclose(np.asarray(one["k"][:, :, :L]),
                               np.asarray(many["k"][:, :, :L]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(one["v"][:, :, :L]),
                               np.asarray(many["v"][:, :, :L]),
                               atol=1e-4)
    causal = init_cache(cfg, 1, T)
    causal = prefill_chunk(params, cfg, causal, jnp.asarray(toks), 0)
    # layer 0 K is mask-independent; deeper layers must differ
    assert float(np.abs(np.asarray(one["k"][1:, :, :L])
                        - np.asarray(causal["k"][1:, :, :L])).max()) \
        > 1e-5


def test_sample_trace_carries_spans_and_serving_runs():
    from repro.api import Engine, sample_trace
    rng = np.random.default_rng(5)
    trace = sample_trace("openvid", 3, rng, max_prompt=64,
                         mean_new_tokens=3, max_new_tokens=4)
    for r in trace:
        assert r.spans is not None
        assert sum(sp.length for sp in r.spans) == r.prompt_len
        assert r.eta == pytest.approx(spans_eta(r.spans))
    assert any(any(sp.attn == "bidirectional" for sp in r.spans)
               for r in trace)
    legacy = sample_trace("openvid", 3, np.random.default_rng(5),
                          max_prompt=64, with_spans=False)
    assert all(r.spans is None for r in legacy)
    # span-bearing trace serves to completion through the runtime
    eng = Engine("internvl3-2b", strategy="dhp", reduced=True, seed=0)
    rep = eng.serving(slots=2, prefill_chunk=16).run(trace)
    assert len(rep.requests) == len(trace)
    assert all(m.n_generated > 0 for m in rep.requests)


# ------------------------------------------------------ loss masking (PR 7)
def test_fill_loss_row_semantics():
    from repro.core.packing import (MODALITY_CLASSES, fill_loss_row,
                                    modality_class)
    L = 8
    cls = np.full(L, -1, np.int32)
    lm = np.zeros(L, np.float32)
    lm[:L - 1] = 1.0                       # base next-token mask
    spans = (ModalitySpan("text", 0, 2),
             ModalitySpan("vision", 2, 3, "bidirectional"),
             ModalitySpan("text", 5, 3))
    fill_loss_row(cls, lm, spans, 0, L)
    # position i labels token i+1: the vision span [2, 5) owns label
    # positions [1, 4), which are excluded from the NLL...
    np.testing.assert_array_equal(lm, [1, 0, 0, 0, 1, 1, 1, 0])
    # ...but still classified for telemetry; everything else is text
    v = modality_class("vision")
    np.testing.assert_array_equal(cls, [0, v, v, v, 0, 0, 0, -1])
    assert MODALITY_CLASSES[v] == "vision"
    # unknown modalities fold into "other", never crash
    assert MODALITY_CLASSES[modality_class("thermal")] == "other"


def test_flatten_group_and_padded_batch_loss_mask_agree():
    from repro.data.pipeline import padded_batch
    seqs = [np.arange(6, dtype=np.int32),
            np.arange(5, dtype=np.int32) + 50]
    spans = [
        (ModalitySpan("text", 0, 2),
         ModalitySpan("vision", 2, 3, "bidirectional"),
         ModalitySpan("text", 5, 1)),
        (ModalitySpan("audio", 0, 4, "bidirectional"),
         ModalitySpan("text", 4, 1)),
    ]
    flat, cu = flatten_group(seqs, bucket=16, spans=spans)
    pad = padded_batch(seqs, bucket=8, spans=spans)
    for batch in (flat, pad):
        assert batch["loss_mask"].shape == batch["mask"].shape
        # loss_mask only ever REMOVES label positions
        assert ((batch["mask"] - batch["loss_mask"]) >= 0).all()
        # a class everywhere a label exists, -1 where none
        assert ((batch["modality_classes"] >= 0)
                == (batch["mask"] > 0)).all()
    # same per-sequence semantics on both layouts
    for i in range(len(seqs)):
        a, b = int(cu[i]), int(cu[i + 1])
        L = b - a
        np.testing.assert_array_equal(flat["loss_mask"][0, a:b],
                                      pad["loss_mask"][i, :L])
        np.testing.assert_array_equal(flat["modality_classes"][0, a:b],
                                      pad["modality_classes"][i, :L])
    # bidirectional audio prefix of seq 1: labels [0, 3) masked out
    np.testing.assert_array_equal(pad["loss_mask"][1, :5],
                                  [0, 0, 0, 1, 0])
    # span-less call emits NEITHER table (pre-span dict preserved)
    assert "loss_mask" not in padded_batch(seqs, bucket=8)


def test_engine_reports_modality_loss_and_replan_telemetry(subproc):
    """Engine-level PR-7 telemetry on 8 devices: per-modality NLL from
    the loss-masked executor, Stage-2 allocate_us, replan_mode, and the
    depth-k batched lookahead window."""
    subproc("""
from repro.api import ClusterSpec, Engine, get_strategy
from repro.core.packing import MODALITY_CLASSES
from repro.data.pipeline import HeterogeneousLoader

loader = HeterogeneousLoader("openvid", 6, 512, seed=3, max_tokens=256,
                             tokens_per_frame=16)
data = next(iter(loader))

# plan_cache OFF + a REPEATED batch: step 1 solves cold ("full"),
# steps 2-3 re-solve the identical instance off the warm DP state
eng = Engine("internvl3-2b", ClusterSpec.auto(mem_budget=900.0),
             reduced=True, seed=0,
             strategy=get_strategy("dhp", plan_cache=False))
hist = eng.train(loader=iter([data, data, data]), steps=3, lookahead=2)
m0 = hist[0]
# span-bearing openvid batches report per-modality NLL; bidirectional
# vision labels are excluded from the TRAINING loss but still reported
assert set(m0.modality_loss) <= set(MODALITY_CLASSES)
assert "text" in m0.modality_loss and "vision" in m0.modality_loss
assert all(v > 0 for v in m0.modality_loss.values())
assert m0.allocate_us > 0
assert m0.replan_mode == "full"
assert all(m.replan_mode == "incremental" for m in hist[1:]), \
    [m.replan_mode for m in hist]
eng.close()

# plan_cache ON: the repeated shape is served from the PlanCache
eng2 = Engine("internvl3-2b", ClusterSpec.auto(mem_budget=900.0),
              reduced=True, seed=0,
              strategy=get_strategy("dhp", plan_cache=True))
hist2 = eng2.train(loader=iter([data, data]), steps=2, lookahead=False)
assert hist2[1].plan_cache_hit and hist2[1].replan_mode == "cache"
eng2.close()
print("telemetry ok", m0.modality_loss, [m.replan_mode for m in hist])
""", n_devices=8)


def test_strategy_prepare_many_window_matches_cold_plans():
    from repro.api import get_strategy
    batches = [[m.seq_info for m in _mm_batch(seed, n=8)]
               for seed in (1, 2, 3)]
    strat = get_strategy("dhp", plan_cache=False).bind(CM, 8, 3000.0)
    strat.prepare_many(batches)
    assert strat.n_pending == 3
    window = [strat.collect() for _ in range(3)]
    strat.close()
    for infos, plan in zip(batches, window):
        cold = get_strategy("dhp", plan_cache=False).bind(
            CM, 8, 3000.0).plan(infos)
        assert plan.structural_hash() == cold.structural_hash()


def test_new_dataset_profiles_span_layouts():
    """PR-7 profiles: image-QA is a single bidirectional vision prefix
    (n_images x 576 patch tokens) + causal QA text; long-form audio is
    one bidirectional audio window + causal transcript — and both feed
    the planner the derived (not hand-set) eta."""
    rng = np.random.default_rng(0)
    qa = sample_mm_batch("imageqa", 32, rng)
    for m in qa:
        bidi = [sp for sp in m.spans if sp.attn == "bidirectional"]
        assert len(bidi) == 1 and bidi[0].modality == "vision"
        assert bidi[0].start == 0 and bidi[0].length % 576 == 0
        assert 1 <= bidi[0].length // 576 <= 4
        assert m.spans[-1].attn == "causal"          # QA text tail
        assert m.eta == pytest.approx(spans_eta(m.spans))
    au = sample_mm_batch("longaudio", 32, rng)
    lens = sorted(m.length for m in au)
    for m in au:
        bidi = [sp for sp in m.spans if sp.attn == "bidirectional"]
        assert len(bidi) == 1 and bidi[0].modality == "audio"
        assert bidi[0].start == 0
    # 30 s .. 15 min at 25 tok/s + 400 transcript tokens
    assert lens[0] >= 30 * 25 + 400
    assert lens[-1] <= 900 * 25 + 400
    # the long tail the profile exists for: >4x spread in one batch
    assert lens[-1] / lens[0] > 4
