"""Compatibility layer for `hypothesis` property tests.

When the real `hypothesis` package is installed (the `[test]` extra in
pyproject.toml) this module re-exports it unchanged. When it is not —
the bare container only ships pytest — a minimal deterministic sampler
stands in: `@given` draws `max_examples` pseudo-random examples from the
same strategy surface the tests use (`integers`, `floats`, `lists`,
`sampled_from`, plus `.map`), seeded per-test so failures reproduce.

This keeps tier-1 runnable without the dependency while losing only
hypothesis' shrinking and coverage-guided generation, not the checks
themselves.
"""
from __future__ import annotations

try:                                    # pragma: no cover - env dependent
    from hypothesis import given, settings, strategies  # noqa: F401
    from hypothesis import strategies as st             # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20
    _MAX_EXAMPLES_CAP = 100

    class _Strategy:
        """Base: something `.example(rng)` can draw from."""

        def example(self, rng: random.Random):
            raise NotImplementedError

        def map(self, fn):
            return _Mapped(self, fn)

    class _Mapped(_Strategy):
        def __init__(self, inner, fn):
            self.inner, self.fn = inner, fn

        def example(self, rng):
            return self.fn(self.inner.example(rng))

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            # bias toward the bounds — the cases hypothesis finds first
            r = rng.random()
            if r < 0.08:
                return self.lo
            if r < 0.16:
                return self.hi
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            r = rng.random()
            if r < 0.08:
                return self.lo
            if r < 0.16:
                return self.hi
            return rng.uniform(self.lo, self.hi)

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return rng.choice(self.options)

    class _Lists(_Strategy):
        def __init__(self, elem, min_size=0, max_size=10):
            self.elem = elem
            self.min_size = min_size
            self.max_size = max_size if max_size is not None else min_size + 10

        def example(self, rng):
            n = rng.randint(self.min_size, self.max_size)
            return [self.elem.example(rng) for _ in range(n)]

    class _StrategiesModule:
        """Duck-typed stand-in for `hypothesis.strategies`."""

        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def lists(elements, *, min_size=0, max_size=None):
            return _Lists(elements, min_size, max_size)

    strategies = st = _StrategiesModule()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None,
                 **_ignored):
        """Records `max_examples` on the (already @given-wrapped) test."""
        def deco(fn):
            fn._compat_max_examples = min(max_examples, _MAX_EXAMPLES_CAP)
            return fn
        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for i in range(n):
                    drawn = [s.example(rng) for s in strats]
                    try:
                        fn(*args, *drawn, **kwargs)
                    except Exception as e:   # noqa: BLE001
                        raise AssertionError(
                            f"falsifying example #{i} "
                            f"(seed={seed}): {drawn!r}") from e
            # pytest resolves fixtures from inspect.signature(), which
            # follows __wrapped__ back to the strategy-parameterised
            # original — drop it so the test collects as zero-arg.
            del wrapper.__wrapped__
            return wrapper
        return deco


__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
