"""Trip-count-aware HLO analysis: validated on hand-written HLO and on a
real compiled scan whose true FLOPs are known analytically."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (analyze_hlo, parse_computations,
                                       _shape_bytes)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[2], s32[4])") == 8 + 16
    assert _shape_bytes("pred[]") == 1


def test_scan_flops_multiplied_by_trip_count():
    """A scan of N matmuls must report N x the single-matmul FLOPs."""
    N, D = 7, 64
    w = jnp.eye(D)

    def step(x, _):
        return x @ w, None

    def f(x):
        y, _ = jax.lax.scan(step, x, None, length=N)
        return y

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((D, D), jnp.float32)).compile()
    tot = analyze_hlo(compiled.as_text())
    expected = N * 2 * D * D * D
    assert tot.flops == pytest.approx(expected, rel=0.05), (
        tot.flops, expected)


def test_unrolled_matches_scan():
    D = 32
    w = jnp.eye(D)

    def f_unrolled(x):
        for _ in range(4):
            x = x @ w
        return x

    def f_scan(x):
        return jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=4)[0]

    sds = jax.ShapeDtypeStruct((D, D), jnp.float32)
    t1 = analyze_hlo(jax.jit(f_unrolled).lower(sds).compile().as_text())
    t2 = analyze_hlo(jax.jit(f_scan).lower(sds).compile().as_text())
    assert t1.flops == pytest.approx(t2.flops, rel=0.05)


def test_parse_computations_entry():
    def f(x):
        return jnp.sin(x) @ x
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps = parse_computations(compiled.as_text())
    assert "__ENTRY__" in comps


def test_fusion_dus_counted_as_window_write():
    """A scan-stacking fusion (dynamic-update-slice of a while-carried
    buffer, possibly through converts) moves only the updated window,
    not the whole buffer."""
    text = """
HloModule test

%fused_dus (param_0: s32[], param_1: bf16[100,64,64], param_2: bf16[64,64]) -> bf16[100,64,64] {
  %param_1 = bf16[100,64,64]{2,1,0} parameter(1)
  %convert.1 = f32[100,64,64]{2,1,0} convert(%param_1)
  %param_2 = bf16[64,64]{1,0} parameter(2)
  %convert.2 = f32[64,64]{1,0} convert(%param_2)
  %bitcast.1 = f32[1,64,64]{2,1,0} bitcast(%convert.2)
  %param_0 = s32[] parameter(0)
  %c0 = s32[] constant(0)
  %dus = f32[100,64,64]{2,1,0} dynamic-update-slice(%convert.1, %bitcast.1, %param_0, %c0, %c0)
  ROOT %convert.3 = bf16[100,64,64]{2,1,0} convert(%dus)
}

ENTRY %main (i: s32[], buf: bf16[100,64,64], upd: bf16[64,64]) -> bf16[100,64,64] {
  %i = s32[] parameter(0)
  %buf = bf16[100,64,64]{2,1,0} parameter(1)
  %upd = bf16[64,64]{1,0} parameter(2)
  ROOT %f = bf16[100,64,64]{2,1,0} fusion(%i, %buf, %upd), kind=kLoop, calls=%fused_dus
}
"""
    tot = analyze_hlo(text)
    # write: f32 window 16384 B; read: bf16 update operand 8192 B.
    # The 100x64x64 buffer itself must NOT be counted (aliased in-place).
    assert tot.hbm_bytes < 100_000, tot.hbm_bytes
    assert tot.hbm_bytes >= 16384 + 8192


def test_fusion_dynamic_slice_reads_window_only():
    """A fusion that only dynamic-slices a big buffer reads the slice."""
    text = """
HloModule test

%fused_ds (param_0: bf16[100,64,64], param_1: s32[]) -> bf16[64,64] {
  %param_0 = bf16[100,64,64]{2,1,0} parameter(0)
  %param_1 = s32[] parameter(1)
  %c0 = s32[] constant(0)
  %ds = bf16[1,64,64]{2,1,0} dynamic-slice(%param_0, %param_1, %c0, %c0), dynamic_slice_sizes={1,64,64}
  ROOT %b = bf16[64,64]{1,0} bitcast(%ds)
}

ENTRY %main (buf: bf16[100,64,64], i: s32[]) -> bf16[64,64] {
  %buf = bf16[100,64,64]{2,1,0} parameter(0)
  %i = s32[] parameter(1)
  ROOT %f = bf16[64,64]{1,0} fusion(%buf, %i), kind=kLoop, calls=%fused_ds
}
"""
    tot = analyze_hlo(text)
    assert tot.hbm_bytes < 50_000, tot.hbm_bytes   # not the 800KB buffer


def test_collectives_counted_with_promotion_halving():
    text = """
HloModule test

ENTRY %main (p: bf16[128,128]) -> bf16[128,128] {
  %p = bf16[128,128]{1,0} parameter(0)
  %ar = bf16[128,128]{1,0} all-reduce(%p), to_apply=%add
  ROOT %ar2 = bf16[128,128]{1,0} all-reduce(%ar), to_apply=%add.1_promoted
}
"""
    tot = analyze_hlo(text)
    # first: full 32768 B; second promoted: halved
    assert tot.coll_bytes["all-reduce"] == 32768 + 16384
