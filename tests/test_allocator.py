"""Stage-2 allocator rewrite (PR 7): the vectorized solver, the
incremental warm-start solver and the lookahead batch API must all be
BIT-IDENTICAL to the legacy pure-Python DP (`allocate_reference`) —
same degrees, same makespan — and match brute force on small instances,
across random ragged batches including span-bearing ones."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CostCoeffs, CostModel, DHPScheduler, Hardware,
                        IncrementalAllocator, PlanCache, SeqInfo,
                        allocate, allocate_bruteforce, allocate_many,
                        allocate_reference, pack_sequences,
                        sample_mm_batch)
from repro.core.packing import AtomicGroup

COEFFS = CostCoeffs(a1=1e-9, a2=1e-5, b1=1e-3, a3=1e-6, b2=1e-4,
                    m_token=1.0, m_ms=0.0)
CM = CostModel(COEFFS, Hardware(intra_bw=50, inter_bw=6, ranks_per_node=8))


def _groups(rng, n_groups, n_ranks, *, with_spans=False):
    """Random feasible instance: sum(d_min) <= n_ranks, random lengths,
    etas drawn either scalar or DERIVED from synthesized span layouts."""
    if with_spans:
        mm = sample_mm_batch("openvid", n_groups, rng, max_tokens=4096)
        seqs = [m.seq_info for m in mm]
    else:
        seqs = [SeqInfo(length=int(rng.integers(64, 4096)),
                        eta=float(rng.choice([0.0, 0.25, 1.0])),
                        seq_id=i)
                for i in range(n_groups)]
    slack = n_ranks - n_groups
    groups = []
    for i, s in enumerate(seqs):
        d_min = 1 + int(rng.integers(0, slack + 1)) if slack > 0 else 1
        slack -= d_min - 1
        groups.append(AtomicGroup(seqs=[s], d_min=d_min,
                                  capacity=1e12, used=0.0))
    return groups


def _same(a, b):
    return a.degrees == b.degrees and a.makespan == b.makespan


# ------------------------------------------------------- bit-equality
@given(st.integers(0, 10 ** 6), st.integers(1, 6),
       st.sampled_from([True, False]), st.sampled_from([True, False]))
@settings(max_examples=40, deadline=None)
def test_vectorized_matches_reference(seed, n_groups, uar, spans):
    rng = np.random.default_rng(seed)
    n_ranks = int(rng.integers(n_groups, 17))
    groups = _groups(rng, n_groups, n_ranks, with_spans=spans)
    ref = allocate_reference(groups, n_ranks, CM.group_time,
                             use_all_ranks=uar)
    vec = allocate(groups, n_ranks, CM.group_time, use_all_ranks=uar)
    assert _same(vec, ref), (vec, ref)
    assert vec.cost_ms >= 0 and vec.dp_ms >= 0


@given(st.integers(0, 10 ** 6), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_vectorized_matches_bruteforce_small(seed, n_groups):
    rng = np.random.default_rng(seed)
    n_ranks = int(rng.integers(n_groups, 7))
    groups = _groups(rng, n_groups, n_ranks)
    vec = allocate(groups, n_ranks, CM.group_time)
    bf = allocate_bruteforce(groups, n_ranks, CM.group_time)
    assert vec.degrees == bf.degrees
    assert vec.makespan == pytest.approx(bf.makespan)


@given(st.integers(0, 10 ** 6), st.integers(2, 6),
       st.sampled_from([True, False]))
@settings(max_examples=30, deadline=None)
def test_incremental_matches_reference_on_perturbed_stream(seed,
                                                           n_groups, uar):
    """A stream of suffix-perturbed instances: the warm-started solver
    must stay bit-identical to cold reference solves at every step."""
    rng = np.random.default_rng(seed)
    n_ranks = int(rng.integers(n_groups, 17))
    groups = _groups(rng, n_groups, n_ranks)
    inc = IncrementalAllocator()
    for _ in range(4):
        ref = allocate_reference(groups, n_ranks, CM.group_time,
                                 use_all_ranks=uar)
        warm = inc(groups, n_ranks, CM.group_time, use_all_ranks=uar)
        assert _same(warm, ref)
        # perturb the LAST group's length (same d_min -> same totals)
        g = groups[-1]
        s = g.seqs[0]
        groups = groups[:-1] + [dataclasses.replace(
            g, seqs=[dataclasses.replace(s, length=s.length + 1)])]


def test_incremental_reuses_prefix_rows():
    rng = np.random.default_rng(7)
    groups = _groups(rng, 6, 16)
    inc = IncrementalAllocator()
    first = inc(groups, 16, CM.group_time)
    assert first.mode == "full" and first.rows_reused == 0
    g = groups[-1]
    s = g.seqs[0]
    perturbed = groups[:-1] + [dataclasses.replace(
        g, seqs=[dataclasses.replace(s, length=s.length + 1)])]
    second = inc(perturbed, 16, CM.group_time)
    assert second.mode == "incremental"
    assert second.rows_reused == len(groups) - 1
    # identical instance again -> full prefix reuse, still identical
    third = inc(perturbed, 16, CM.group_time)
    assert _same(third, second)


def test_incremental_falls_back_on_changed_rank_total():
    """Changing the total d_min reserve shifts EVERY row's feasible
    window, so no prefix is reusable — must degrade to a full solve and
    stay correct."""
    rng = np.random.default_rng(3)
    groups = _groups(rng, 4, 16)
    inc = IncrementalAllocator()
    inc(groups, 16, CM.group_time)
    bumped = [dataclasses.replace(groups[0], d_min=groups[0].d_min + 1)
              ] + groups[1:]
    got = inc(bumped, 16, CM.group_time)
    assert got.mode == "full"
    assert _same(got, allocate_reference(bumped, 16, CM.group_time))


def test_allocate_many_matches_individual_solves():
    rng = np.random.default_rng(11)
    batches = [_groups(rng, 5, 16) for _ in range(3)]
    many = allocate_many(batches, 16, CM.group_time)
    for b, a in zip(batches, many):
        assert _same(a, allocate_reference(b, 16, CM.group_time))


# ------------------------------------------------- vectorized cost rows
@given(st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_group_time_vector_bit_equal(seed):
    rng = np.random.default_rng(seed)
    seqs = [SeqInfo(length=int(rng.integers(64, 8192)),
                    eta=float(rng.uniform(0, 1)), seq_id=i)
            for i in range(int(rng.integers(1, 5)))]
    degrees = np.arange(1, 17)
    vec = CM.group_time_vector(seqs, degrees)
    for d, v in zip(degrees, vec):
        assert v == CM.group_time(seqs, int(d))     # exact, not approx


# ----------------------------------------------------- solver timing split
def test_solver_ms_split():
    rng = np.random.default_rng(0)
    groups = _groups(rng, 6, 16)
    for fn in (allocate, allocate_reference):
        a = fn(groups, 16, CM.group_time)
        assert a.solver_ms > 0
        assert a.cost_ms > 0 and a.dp_ms >= 0


def test_scheduler_surfaces_allocate_split_and_replan_mode():
    rng = np.random.default_rng(5)
    mm = sample_mm_batch("openvid", 12, rng, max_tokens=2048)
    seqs = [m.seq_info for m in mm]
    sched = DHPScheduler(CM, 8, mem_budget=4096.0)
    plan = sched.schedule(seqs)
    assert plan.replan_mode == "full"
    assert "allocate_cost" in plan.stage_ms
    assert "allocate_dp" in plan.stage_ms
    # identical histogram again -> every DP row warm
    plan2 = sched.schedule(seqs)
    assert plan2.replan_mode == "incremental"
    assert plan2.degree_histogram == plan.degree_histogram


def test_scheduler_incremental_equals_cold():
    """The warm-started scheduler must emit structurally identical plans
    to a cold scheduler at every step of a drifting stream."""
    rng = np.random.default_rng(9)
    sched = DHPScheduler(CM, 8, mem_budget=4096.0)
    for _ in range(4):
        mm = sample_mm_batch("openvid", 10, rng, max_tokens=2048)
        seqs = [m.seq_info for m in mm]
        warm = sched.schedule(seqs)
        cold = DHPScheduler(CM, 8, mem_budget=4096.0,
                            incremental=False).schedule(seqs)
        assert warm.structural_hash() == cold.structural_hash()


# ------------------------------------------------------- PlanCache.nearest
def test_plan_cache_nearest_prefers_largest_overlap():
    sched = DHPScheduler(CM, 8, mem_budget=4096.0)
    cache = PlanCache()
    assert cache.nearest([SeqInfo(length=256, seq_id=0)]) is None
    a = [SeqInfo(length=256, seq_id=i) for i in range(4)]
    b = [SeqInfo(length=1024, seq_id=i) for i in range(4)]
    plan_a, plan_b = sched.schedule(a), sched.schedule(b)
    cache.store(a, plan_a)
    cache.store(b, plan_b)
    near = [SeqInfo(length=1024, seq_id=i) for i in range(3)] + \
        [SeqInfo(length=256, seq_id=3)]
    stats = dict(cache.stats)
    hit = cache.nearest(near)
    assert hit is not None
    assert hit.structural_hash() == plan_b.structural_hash()
    # nearest() is a warm-start REFERENCE: the serve-path hit/miss
    # counters stay untouched, but the lookup lands in the dedicated
    # nearest_* accounting (PR 9 observability)
    assert cache.stats["hits"] == stats["hits"]
    assert cache.stats["misses"] == stats["misses"]
    assert cache.stats["nearest_fallback"] == \
        stats["nearest_fallback"] + 1
    # exact key present -> that entry wins outright
    exact = cache.nearest(b)
    assert exact.structural_hash() == plan_b.structural_hash()
    assert cache.stats["nearest_exact"] == stats["nearest_exact"] + 1
    # the empty-cache probe at the top of the test was counted too
    assert cache.stats["nearest_none"] == 1
