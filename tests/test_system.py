"""End-to-end behaviour tests for the paper's system: the full
global-batch -> micro-batch planner -> BFD packing -> 2D-DP -> plan
pipeline, validated against the formal constraints of §4.1 (Eqs. 3-6)
and the paper's qualitative claims (Table 4, §6.3 overlap)."""
import dataclasses

import numpy as np
import pytest

from repro.core import (CostModel, DHPScheduler, analytic_coeffs,
                        sample_batch, static_plan)
from repro.core.cost_model import SeqInfo
from repro.core.simulator import ClusterSimulator

COEFFS = dataclasses.replace(
    analytic_coeffs(hidden=2048, n_layers=24, n_heads=16, kv_heads=8,
                    ffn=8192, vocab=32000),
    m_ms=0.0)
CM = CostModel(COEFFS)


def _budget(seqs, n_ranks, frac=0.35):
    """A memory budget that forces degree>1 for the longest sequences."""
    longest = max(s.length for s in seqs)
    return longest * COEFFS.m_token * frac


def _validate_plan_constraints(plan, seqs, n_ranks, budget):
    """Eqs. (3)-(6) must hold for every micro-batch of the plan."""
    all_ids = {s.seq_id for s in seqs}
    by_id = {s.seq_id: s for s in seqs}
    seen = set()
    for mb in plan.micro_batches:
        ranks = 0
        for g in mb.groups:
            # Eq. 5: exclusive assignment
            for sid in g.seq_ids:
                assert sid not in seen, f"sequence {sid} assigned twice"
                seen.add(sid)
            # Eq. 3: per-rank memory limit
            mem = CM.memory([by_id[sid] for sid in g.seq_ids])
            assert mem <= budget * g.degree + 1e-6, \
                f"memory {mem:.1f} > E*d = {budget * g.degree:.1f}"
            ranks += g.degree
        # Eq. 6: rank budget per micro-batch
        assert ranks <= n_ranks
        # makespan is the max group time (Eq. 2 objective)
        assert mb.makespan == pytest.approx(
            max(g.est_time for g in mb.groups))
    # Eq. 5 (completeness): every sequence scheduled exactly once
    assert seen == all_ids


@pytest.mark.parametrize("dataset", ["msrvtt", "internvid", "openvid",
                                     "imageqa", "longaudio"])
@pytest.mark.parametrize("n_ranks", [7, 8, 24, 64])
def test_plan_satisfies_paper_constraints(dataset, n_ranks):
    seqs = sample_batch(dataset, 64, np.random.default_rng(3),
                        max_tokens=60_000)
    budget = _budget(seqs, n_ranks)
    plan = DHPScheduler(CM, n_ranks, budget).schedule(seqs)
    _validate_plan_constraints(plan, seqs, n_ranks, budget)


def test_dhp_beats_or_matches_static_everywhere():
    """The dynamic plan's estimated makespan must never be worse than the
    best static plan under the SAME cost model (it can always fall back
    to a uniform partition)."""
    for dataset in ("msrvtt", "internvid", "openvid"):
        for n_ranks in (8, 16, 64):
            seqs = sample_batch(dataset, 96, np.random.default_rng(11),
                                max_tokens=80_000)
            budget = _budget(seqs, n_ranks)
            dhp = DHPScheduler(CM, n_ranks, budget).schedule(seqs)
            static = static_plan(seqs, CM, n_ranks, budget)
            assert dhp.total_time_est <= static.total_time_est * 1.0001, \
                (dataset, n_ranks, dhp.total_time_est,
                 static.total_time_est)


def test_diverse_data_gets_less_consistent_degrees():
    """Paper Table 4 / §6.5: 'for relatively uniform data (MSRVTT), the
    CP degrees remain more consistent' — i.e. the modal degree covers a
    larger share of groups than on long-tailed OpenVid. Uses the same
    absolute-hardware calibration as benchmarks/bench_case_study."""
    cm = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                                   kv_heads=4, ffn=18944, vocab=152000))
    budget = 3e9
    rng = np.random.default_rng(7)

    def top_share(ds):
        seqs = sample_batch(ds, 64, rng, max_tokens=262144)
        h = DHPScheduler(cm, 32, budget, balance_packing=False,
                         serial_fallback=False).schedule(
            seqs).degree_histogram
        return max(h.values()) / sum(h.values()), h

    share_open, h_open = top_share("openvid")
    share_msr, h_msr = top_share("msrvtt")
    assert share_msr > share_open, (h_msr, h_open)
    # and the dynamic mesh actually uses heterogeneous degrees on openvid
    assert len(h_open) >= 3, h_open


def test_scheduling_overlappable_with_compute():
    """§6.3: scheduling latency must stay below the batch compute time so
    the producer-consumer overlap hides it completely."""
    seqs = sample_batch("openvid", 512, np.random.default_rng(7),
                        max_tokens=60_000)
    budget = _budget(seqs, 64)
    plan = DHPScheduler(CM, 64, budget).schedule(seqs)
    assert plan.schedule_ms / 1e3 < plan.total_time_est, \
        (plan.schedule_ms, plan.total_time_est)


def test_simulator_speedup_positive_on_heterogeneous_data():
    """Fig. 4/6 direction: on long-tailed data DHP improves over the best
    static baseline under the shared cost model."""
    seqs = sample_batch("openvid", 256, np.random.default_rng(13),
                        max_tokens=100_000)
    sim = ClusterSimulator(CM, n_ranks=32, mem_budget=_budget(seqs, 32))
    res = sim.compare(seqs)
    best_static = min(res["megatron-lm"].iter_time_s,
                      res["deepspeed"].iter_time_s)
    assert res["dhp"].iter_time_s <= best_static
    assert res["dhp-faithful"].iter_time_s <= best_static * 1.02


def test_degenerate_batches():
    """System stays correct on edge-case batches."""
    n_ranks, budget = 8, 1e9
    # single short sequence
    plan = DHPScheduler(CM, n_ranks, budget).schedule(
        [SeqInfo(length=128, seq_id=0)])
    _validate_plan_constraints(plan, [SeqInfo(length=128, seq_id=0)],
                               n_ranks, budget)
    # all-identical sequences
    seqs = [SeqInfo(length=4096, seq_id=i) for i in range(16)]
    plan = DHPScheduler(CM, n_ranks, budget).schedule(seqs)
    _validate_plan_constraints(plan, seqs, n_ranks, budget)
    # one sequence that needs every rank
    tight = CM.memory([SeqInfo(length=65_536)]) / 8 * 1.01
    seqs = [SeqInfo(length=65_536, seq_id=0)]
    plan = DHPScheduler(CM, 8, tight).schedule(seqs)
    _validate_plan_constraints(plan, seqs, 8, tight)
    assert plan.micro_batches[0].groups[0].degree == 8


def test_eta_full_attention_raises_cost_and_degree():
    """Eq. 8's mask-efficiency factor: vision-heavy (eta=1) sequences
    cost more and therefore earn higher CP degrees."""
    n_ranks = 16
    text = [SeqInfo(length=16_384, eta=0.0, seq_id=0)]
    vision = [SeqInfo(length=16_384, eta=1.0, seq_id=0)]
    assert CM.compute_time(vision, 1) > CM.compute_time(text, 1)
    budget = CM.memory(text) / 2
    d_text = DHPScheduler(CM, n_ranks, budget).schedule(
        text).micro_batches[0].groups[0].degree
    d_vis = DHPScheduler(CM, n_ranks, budget).schedule(
        vision).micro_batches[0].groups[0].degree
    assert d_vis >= d_text


def test_end_to_end_training_dynamic_regrouping(subproc):
    """Full system on 8 host devices: heterogeneous loader -> async DHP
    scheduler -> executor; loss must decrease and the plan must actually
    use heterogeneous degrees across steps."""
    subproc("""
import dataclasses, jax, numpy as np
from repro.configs import get_config
from repro.core import CostModel, DHPScheduler, analytic_coeffs
from repro.core.executor import DHPExecutor
from repro.data.pipeline import HeterogeneousLoader
from repro.models.model import init_params
from repro.training.optimizer import AdamW
from repro.training.train_step import TrainState

cfg = get_config("internvl3-2b").reduced().with_(family="dense", vlm=None)
params = init_params(jax.random.PRNGKey(0), cfg)
opt = AdamW(lr=3e-3)
state = TrainState(params, opt.init(params))
loader = HeterogeneousLoader("openvid", 12, cfg.vocab, seed=3,
                             max_tokens=512, tokens_per_frame=16)
coeffs = dataclasses.replace(
    analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                    n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    ffn=cfg.d_ff, vocab=cfg.vocab), m_ms=0.0, m_token=1.0)
sched = DHPScheduler(CostModel(coeffs), 8, mem_budget=900.0)
ex = DHPExecutor(cfg)
losses, degrees = [], set()
it = iter(loader)
for step in range(6):
    data = next(it)
    plan = sched.schedule(data.infos)
    degrees.update(g.degree for mb in plan.micro_batches
                   for g in mb.groups)
    loss, grads = ex.run_plan(state.params, plan, data)
    p, o = opt.update(grads, state.opt, state.params)
    state = TrainState(p, o)
    losses.append(float(loss))
assert losses[-1] < losses[0], losses
assert len(degrees) >= 2, degrees
print("ok", losses[0], "->", losses[-1], "degrees", sorted(degrees))
""", n_devices=8)
