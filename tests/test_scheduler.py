"""DHP core: cost model (Eqs. 7-10), BFD packing, 2D-DP (Alg. 1),
scheduler workflow — unit + hypothesis property tests."""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (CostCoeffs, CostModel, DHPScheduler, Hardware,
                        SeqInfo, allocate, allocate_bruteforce,
                        analytic_coeffs, pack_sequences, sample_batch,
                        static_plan, validate_packing)
from repro.core.packing import AtomicGroup

COEFFS = CostCoeffs(a1=1e-9, a2=1e-5, b1=1e-3, a3=1e-6, b2=1e-4,
                    m_token=1.0, m_ms=0.0)
CM = CostModel(COEFFS, Hardware(intra_bw=50, inter_bw=6, ranks_per_node=8))


def seqs_of(lengths, etas=None):
    etas = etas or [0.0] * len(lengths)
    return [SeqInfo(length=l, eta=e, seq_id=i)
            for i, (l, e) in enumerate(zip(lengths, etas))]


# ---------------------------------------------------------------- cost model
def test_memory_eq7():
    s = seqs_of([100, 200])
    assert CM.memory(s) == pytest.approx(300 * COEFFS.m_token + COEFFS.m_ms)


def test_compute_eq8_eta_factor():
    """Full-attention (eta=1) tokens cost 2x the quadratic term (§4.2)."""
    causal = CM.compute_time(seqs_of([1000]), 1)
    full = CM.compute_time(seqs_of([1000], [1.0]), 1)
    quad = COEFFS.a1 * 1000 ** 2
    assert full - causal == pytest.approx(quad)


def test_comm_eq9_zero_at_degree_1():
    s = seqs_of([4096])
    assert CM.comm_time(s, 1) == 0.0
    assert CM.comm_time(s, 4) > 0.0


def test_overlap_eq10():
    """T = T_cp + T_cm - min(T_cpa, T_cma)."""
    s = seqs_of([8192])
    d = 4
    t = CM.group_time(s, d)
    expected = (CM.compute_time(s, d) + CM.comm_time(s, d)
                - min(CM.attn_compute_time(s, d), CM.attn_comm_time(s, d)))
    assert t == pytest.approx(expected)


def test_ring_bandwidth_topology():
    hw = Hardware(intra_bw=50, inter_bw=6, ranks_per_node=8)
    assert hw.ring_bandwidth(8) == 50
    assert hw.ring_bandwidth(9) == 6    # crosses the node boundary


def test_min_degree_ceil():
    cm = CostModel(dataclasses.replace(COEFFS, m_token=2.0))
    assert cm.min_degree(seqs_of([100]), budget=150.0) == 2  # 200B / 150B


# ---------------------------------------------------------------- packing
def test_bfd_packs_short_into_long_bins():
    s = seqs_of([1000, 100, 100])
    groups = pack_sequences(s, CM, budget=1300.0)
    assert len(groups) == 1           # shorts best-fit into the long bin
    validate_packing(groups, CM, 1300.0)


def test_bfd_opens_new_bin_when_full():
    s = seqs_of([1000, 900, 800])
    groups = pack_sequences(s, CM, budget=1000.0)
    assert len(groups) == 3
    validate_packing(groups, CM, 1000.0)


def test_bfd_min_degree_for_long_seq():
    s = seqs_of([2500])
    groups = pack_sequences(s, CM, budget=1000.0)
    assert groups[0].d_min == 3       # ceil(2500/1000)


def test_bfd_rejects_oversized():
    with pytest.raises(ValueError):
        pack_sequences(seqs_of([10_000]), CM, budget=1000.0, max_degree=4)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(10, 5000), min_size=1, max_size=40),
       st.floats(600.0, 5000.0))
def test_bfd_invariants(lengths, budget):
    """Every sequence lands in exactly one bin; Eq. (3) always holds."""
    s = seqs_of(lengths)
    groups = pack_sequences(s, CM, budget)
    packed = sorted(x.seq_id for g in groups for x in g.seqs)
    assert packed == list(range(len(s)))          # Conds (4)+(5)
    validate_packing(groups, CM, budget)           # Cond (3)


# ---------------------------------------------------------------- allocator
def _groups_from(lengths, budget=4000.0):
    return pack_sequences(seqs_of(lengths), CM, budget)


def test_dp_matches_bruteforce_small():
    g = _groups_from([3000, 2000, 500])
    a = allocate(g, 6, CM.group_time, use_all_ranks=False)
    b = allocate_bruteforce(g, 6, CM.group_time)
    assert a.makespan == pytest.approx(b.makespan)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(100, 8000), min_size=1, max_size=5),
       st.integers(2, 8))
def test_dp_optimality_property(lengths, n_ranks):
    """Alg. 1 is exactly optimal for the separable makespan objective."""
    g = _groups_from(lengths, budget=9000.0)
    if sum(x.d_min for x in g) > n_ranks:
        return
    a = allocate(g, n_ranks, CM.group_time, use_all_ranks=False)
    b = allocate_bruteforce(g, n_ranks, CM.group_time)
    assert a.makespan == pytest.approx(b.makespan, rel=1e-9)


def test_dp_respects_min_degrees_and_rank_budget():
    g = _groups_from([7000, 6000, 2000], budget=3000.0)
    a = allocate(g, 10, CM.group_time)
    for gr, d in zip(g, a.degrees):
        assert d >= gr.d_min
    assert a.ranks_used <= 10


def test_dp_infeasible_raises():
    g = _groups_from([9000, 9000], budget=3000.0)   # needs 3+3 ranks
    with pytest.raises(ValueError):
        allocate(g, 4, CM.group_time)


def test_non_power_of_two_degrees_appear():
    """The paper's headline flexibility: degrees like 3, 5, 6."""
    rng = np.random.default_rng(3)
    seqs = sample_batch("openvid", 64, rng, max_tokens=40_000)
    cm = CostModel(dataclasses.replace(
        COEFFS, m_token=1.0, m_ms=0.0))
    sched = DHPScheduler(cm, 13, mem_budget=9000.0)
    plan = sched.schedule(seqs)
    degrees = set(plan.degree_histogram)
    assert any(d not in (1, 2, 4, 8, 16) for d in degrees), degrees


# ---------------------------------------------------------------- scheduler
def test_plan_covers_all_sequences_once():
    rng = np.random.default_rng(0)
    seqs = sample_batch("openvid", 128, rng, max_tokens=65536)
    cm = CostModel(analytic_coeffs(hidden=2048, n_layers=24, n_heads=16,
                                   kv_heads=8, ffn=8192, vocab=50000))
    sched = DHPScheduler(cm, 16, mem_budget=8e9)
    plan = sched.schedule(seqs)
    ids = sorted(i for mb in plan.micro_batches for g in mb.groups
                 for i in g.seq_ids)
    assert ids == list(range(128))
    for mb in plan.micro_batches:
        assert sum(g.degree for g in mb.groups) <= 16       # Cond (6)


def test_async_prepare_collect():
    rng = np.random.default_rng(1)
    seqs = sample_batch("msrvtt", 32, rng, max_tokens=30000)
    cm = CostModel(analytic_coeffs(hidden=1024, n_layers=12, n_heads=8,
                                   kv_heads=8, ffn=4096, vocab=32000))
    sched = DHPScheduler(cm, 8, mem_budget=4e9)
    sched.prepare(seqs)
    plan = sched.collect()
    assert plan.micro_batches
    sync = sched.schedule(seqs)
    assert plan.degree_histogram == sync.degree_histogram


def test_static_plan_uses_all_groups():
    rng = np.random.default_rng(2)
    seqs = sample_batch("internvid", 64, rng, max_tokens=30000)
    cm = CostModel(analytic_coeffs(hidden=1024, n_layers=12, n_heads=8,
                                   kv_heads=8, ffn=4096, vocab=32000))
    plan = static_plan(seqs, cm, 16, 8e9)
    assert plan.total_time_est > 0
    ids = sorted(i for mb in plan.micro_batches for g in mb.groups
                 for i in g.seq_ids)
    assert ids == list(range(64))


def test_deepspeed_power_of_two_restriction():
    rng = np.random.default_rng(2)
    cm = CostModel(dataclasses.replace(COEFFS, m_token=1e6))
    seqs = sample_batch("openvid", 16, rng, max_tokens=20000)
    p = static_plan(seqs, cm, 16, 8e9, power_of_two=True)
    for mb in p.micro_batches:
        for g in mb.groups:
            assert g.degree & (g.degree - 1) == 0     # power of two
