import os
import sys

import pytest

# Tests must see exactly ONE device (the dry-run sets its own 512-device
# flag in a separate process). Never set XLA_FLAGS here.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run_in_subprocess(script: str, n_devices: int = 8, timeout: int = 560):
    """Run a python snippet with a multi-device host platform."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess
