"""Observability subsystem (ISSUE 9): tracer schema + concurrency +
ring buffer, metrics registry semantics, run-report analytics against
hand-computed values, StepMetrics serialization, and the traced
Engine.train integration path."""
import json
import threading

import pytest

from repro.obs import (Counter, Gauge, GroupRecord, Histogram,
                       MetricsRegistry, NULL_TRACER, RunRecorder, Tracer,
                       build_report, get_tracer, scale_fit,
                       scale_fit_mape, straggler_scores, tracing,
                       validate_trace, wave_stats)
from repro.obs.trace import PID_HOST, PID_RANKS


# ---------------------------------------------------------------- tracer
def test_tracer_chrome_schema_and_tracks():
    tr = Tracer()
    with tr.span("solve", "planner", args={"seqs": 4}):
        pass
    tr.complete("stage", tr._t0, 0.001, "sched")  # explicit timestamps
    tr.instant("marker", args={"step": 1})
    tr.counter("kv", {"occupancy": 0.5, "blocks": 12})
    tr.rank_span("execute", 3, tr._t0, 0.25, args={"tokens": 128})

    obj = tr.to_json()
    n = validate_trace(obj)                  # raises on any violation
    events = obj["traceEvents"]
    assert n == len(events)
    # the document survives real serialization
    assert validate_trace(json.loads(json.dumps(obj))) == n

    by_ph = {}
    for ev in events:
        by_ph.setdefault(ev["ph"], []).append(ev)
    assert {"X", "i", "C", "M"} <= set(by_ph)
    # metadata names both processes and every registered track
    meta = {(e["pid"], e["tid"], e["name"]) for e in by_ph["M"]}
    assert (PID_HOST, 0, "process_name") in meta
    assert (PID_RANKS, 0, "process_name") in meta
    assert (PID_RANKS, 3, "thread_name") in meta
    # the rank span landed on the "ranks" process, tid == rank index
    rank_evs = [e for e in by_ph["X"] if e["pid"] == PID_RANKS]
    assert [e["tid"] for e in rank_evs] == [3]
    assert rank_evs[0]["dur"] == pytest.approx(0.25e6)   # us
    # host spans carry their args through
    solve = next(e for e in by_ph["X"] if e["name"] == "solve")
    assert solve["args"] == {"seqs": 4} and solve["pid"] == PID_HOST


def test_tracer_concurrent_emission_two_threads():
    tr = Tracer()
    n_per = 200

    def worker():
        for i in range(n_per):
            with tr.span("planner_solve", "planner", args={"i": i}):
                pass

    t = threading.Thread(target=worker, name="planner")
    t.start()
    for i in range(n_per):
        with tr.span("main_step", "train", args={"i": i}):
            pass
    t.join()

    obj = tr.to_json()
    validate_trace(obj)
    spans = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 2 * n_per           # nothing lost or torn
    # one distinct host track per python thread
    tids = {e["name"]: {s["tid"] for s in spans if s["name"] == e["name"]}
            for e in spans}
    assert len(tids["main_step"]) == 1 and len(tids["planner_solve"]) == 1
    assert tids["main_step"] != tids["planner_solve"]
    # the planner thread's track is labelled with its thread name
    names = {(e["pid"], e["tid"]): e["args"]["name"]
             for e in obj["traceEvents"] if e["name"] == "thread_name"}
    assert "planner" in names.values()


def test_ring_buffer_evicts_oldest_keeps_newest():
    tr = Tracer(capacity=8)
    with tr.span("first", "c"):
        pass                                  # will be evicted
    for i in range(20):
        tr.complete(f"ev{i}", tr._t0, 0.0, "c")
    assert len(tr) == 8
    assert tr.dropped == 13                   # 21 emitted - 8 kept
    obj = tr.to_json()
    validate_trace(obj)
    kept = [e["name"] for e in obj["traceEvents"] if e["ph"] == "X"]
    assert kept == [f"ev{i}" for i in range(12, 20)]   # newest window
    # track metadata lives OUTSIDE the ring: labels survive eviction
    assert any(e["name"] == "thread_name" for e in obj["traceEvents"])
    assert obj["otherData"]["dropped_events"] == 13


def test_null_tracer_and_tracing_scope():
    assert get_tracer() is NULL_TRACER        # disabled by default
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x", "c", args={"a": 1}):
        pass                                  # true no-op, no error
    NULL_TRACER.complete("x", 0.0, 1.0)
    NULL_TRACER.rank_span("x", 0, 0.0, 1.0)
    NULL_TRACER.counter("x", {"v": 1})
    tr = Tracer()
    with tracing(tr):
        assert get_tracer() is tr
        with tracing(None):                   # None -> NULL_TRACER
            assert get_tracer() is NULL_TRACER
        assert get_tracer() is tr
    assert get_tracer() is NULL_TRACER        # restored on exit


def test_validate_trace_rejects_malformed():
    ok = {"traceEvents": [{"name": "a", "ph": "X", "ts": 0, "dur": 1,
                           "pid": 1, "tid": 0}]}
    assert validate_trace(ok) == 1
    with pytest.raises(ValueError):
        validate_trace([])                    # not a dict
    with pytest.raises(ValueError):
        validate_trace({"traceEvents": [{"ph": "X", "ts": 0, "dur": 1,
                                         "pid": 1, "tid": 0}]})  # no name
    with pytest.raises(ValueError):           # complete event needs dur
        validate_trace({"traceEvents": [{"name": "a", "ph": "X",
                                         "ts": 0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):           # negative timestamp
        validate_trace({"traceEvents": [{"name": "a", "ph": "X",
                                         "ts": -1, "dur": 1, "pid": 1,
                                         "tid": 0}]})
    with pytest.raises(ValueError):           # unknown phase
        validate_trace({"traceEvents": [{"name": "a", "ph": "Z",
                                         "ts": 0, "pid": 1, "tid": 0}]})
    with pytest.raises(ValueError):           # string pid
        validate_trace({"traceEvents": [{"name": "a", "ph": "X",
                                         "ts": 0, "dur": 1, "pid": "1",
                                         "tid": 0}]})


# --------------------------------------------------------------- metrics
def test_metrics_registry_semantics():
    reg = MetricsRegistry()
    reg.counter("steps").inc()
    reg.counter("steps").inc(4)
    with pytest.raises(ValueError):
        reg.counter("steps").inc(-1)          # counters only go up
    reg.gauge("occupancy").set(0.75)
    for v in (1.0, 2.0, 3.0, 10.0):
        reg.histogram("lat").observe(v)
    with pytest.raises(TypeError):
        reg.gauge("steps")                    # kind mismatch

    snap = reg.snapshot()
    assert snap["steps"] == 5                 # counters snapshot scalar
    assert snap["occupancy"] == 0.75
    assert snap["lat"]["count"] == 4
    assert snap["lat"]["sum"] == 16.0
    assert snap["lat"]["min"] == 1.0 and snap["lat"]["max"] == 10.0
    json.dumps(snap)                          # snapshot is JSON-safe

    prev = reg.snapshot()
    reg.counter("steps").inc(2)
    reg.histogram("lat").observe(5.0)
    reg.gauge("occupancy").set(0.5)
    d = reg.delta(prev)
    assert d["steps"] == 2                    # counters report the diff
    assert d["lat"]["count"] == 1 and d["lat"]["sum"] == 5.0
    assert d["occupancy"] == 0.5              # gauges report current

    reg.update_from({"hits": 3, "misses": 1, "label": "x"}, "cache/")
    snap = reg.snapshot()
    assert snap["cache/hits"] == 3            # numeric fields -> gauges
    assert "cache/label" not in snap          # non-numeric skipped


def test_histogram_percentile():
    h = Histogram("h")
    for v in range(1, 101):
        h.observe(float(v))
    assert h.percentile(0.5) == pytest.approx(50.0, abs=1.0)
    assert h.percentile(0.99) == pytest.approx(99.0, abs=1.5)
    assert Counter("c").value == 0 and Gauge("g").value == 0.0


# ---------------------------------------------------------------- report
def _two_wave_recorder():
    """Synthetic run: 8 ranks, 2 waves of 2 groups (degree 4 each);
    the wave-1 group on ranks 4-7 runs 3x slow — every downstream
    number is hand-computable."""
    rec = RunRecorder(n_ranks=8)
    mk = lambda wave, group, start, meas: rec.add(GroupRecord(
        step=0, wave=wave, group=group, start_rank=start, degree=4,
        tokens=512, predicted_s=1.0, measured_s=meas))
    mk(0, 0, 0, 0.010)
    mk(0, 1, 4, 0.010)
    mk(1, 0, 0, 0.010)
    mk(1, 1, 4, 0.030)                        # the injected straggler
    return rec


def test_report_hand_computed_values():
    rec = _two_wave_recorder()
    report = build_report(rec)

    # least-squares wall/predicted scale: sum(p*m)/sum(p^2) = 0.06/4
    assert report.model_error["scale"] == pytest.approx(0.015)
    # every scaled prediction misses by exactly 50%
    assert report.model_error["mape_pct"] == pytest.approx(50.0)
    assert report.model_error["n_samples"] == 4
    for w in report.model_error["per_wave"]:
        assert w["mape_pct"] == pytest.approx(50.0)

    # imbalance = max/mean group time per wave: 1.0 then 0.03/0.02
    waves = wave_stats(rec.records)
    assert [w["imbalance"] for w in waves] == \
        pytest.approx([1.0, 1.5])
    assert waves[1]["makespan_s"] == pytest.approx(0.030)
    assert report.imbalance["mean"] == pytest.approx(1.25)
    assert report.imbalance["max"] == pytest.approx(1.5)
    assert report.imbalance["n_waves"] == 2

    # straggler scores: ranks 0-3 mean(1.0, 0.5), ranks 4-7 mean(1.0, 1.5)
    scores = straggler_scores(rec.records, 8)
    for r in range(4):
        assert scores[r]["score"] == pytest.approx(0.75)
    for r in range(4, 8):
        assert scores[r]["score"] == pytest.approx(1.25)
    assert report.stragglers["worst_rank"] in (4, 5, 6, 7)
    assert report.stragglers["flagged"] == [4, 5, 6, 7]   # > 1.2

    # document round-trips through real JSON with string score keys
    doc = json.loads(json.dumps(report.to_json()))
    assert doc["version"] == 1
    assert doc["stragglers"]["scores"]["4"]["score"] == \
        pytest.approx(1.25)
    assert "run report" in report.summary()


def test_report_excludes_compile_tainted_waves():
    rec = _two_wave_recorder()
    # taint wave 1 (the slow one) with a compile
    rec.records[3].compiled = True
    report = build_report(rec)
    # imbalance/straggler stats now use only the clean wave 0
    assert report.imbalance["n_waves"] == 1
    assert report.imbalance["max"] == pytest.approx(1.0)
    assert report.imbalance["clean"] is True
    scores = report.stragglers["scores"]
    assert all(scores[r]["waves"] == 1 for r in range(8))
    # MAPE sample drops the compiled group (scale refits on the rest)
    assert report.model_error["n_samples"] == 3
    assert report.model_error["scale"] == pytest.approx(0.010)
    assert report.model_error["mape_pct"] == pytest.approx(0.0)

    # all-tainted run: fall back to using everything rather than
    # reporting an empty document (short smoke runs)
    for r in rec.records:
        r.compiled = True
    fallback = build_report(rec)
    assert fallback.imbalance["n_waves"] == 2
    assert fallback.imbalance["clean"] is False
    assert fallback.model_error["n_samples"] == 4


def test_scale_fit_edge_cases():
    assert scale_fit([], []) == 0.0
    assert scale_fit([1.0, 2.0], [2.0, 4.0]) == pytest.approx(2.0)
    mape, scale, n = scale_fit_mape([1.0, 1.0], [0.0, 2.0])
    assert n == 1                             # zero measurement skipped
    assert scale == pytest.approx(2.0)
    assert mape == pytest.approx(0.0)
    assert scale_fit_mape([], []) == (0.0, 0.0, 0)


def test_group_record_round_trip():
    r = GroupRecord(step=2, wave=1, group=0, start_rank=4, degree=4,
                    tokens=256, predicted_s=1.5, measured_s=0.02,
                    compiled=True)
    assert list(r.ranks) == [4, 5, 6, 7]
    back = GroupRecord.from_json(json.loads(json.dumps(r.to_json())))
    assert back == r


# ----------------------------------------------- StepMetrics round-trip
def test_step_metrics_round_trip():
    from repro.api.engine import (StepMetrics, metrics_from_json,
                                  metrics_to_json)
    m = StepMetrics(step=3, loss=1.25, tokens=4096, step_time_s=0.5,
                    strategy="dhp", schedule_ms=0.7, solver_ms=0.2,
                    stage_ms={"pack": 0.1},
                    degree_histogram={1: 4, 2: 2},
                    model_error_pct=12.5,
                    plan_cache={"hits": 2, "misses": 1})
    doc = json.loads(json.dumps(metrics_to_json([m])))
    assert doc["version"] == 1
    back = metrics_from_json(doc)
    assert len(back) == 1
    b = back[0]
    assert b.step == 3 and b.loss == 1.25
    assert b.degree_histogram == {1: 4, 2: 2}   # int keys restored
    assert b.model_error_pct == 12.5
    assert b.plan_cache == {"hits": 2, "misses": 1}
    # unknown fields from future versions are ignored, not fatal
    obj = m.to_json()
    obj["some_future_field"] = 1
    assert StepMetrics.from_json(obj).step == 3


# ------------------------------------------------- engine integration
def test_traced_train_produces_valid_trace_and_report(subproc, tmp_path):
    out = subproc("""
import json
from repro.api import ClusterSpec, Engine, get_strategy
from repro.configs import get_config
from repro.data.pipeline import HeterogeneousLoader
from repro.obs.trace import PID_HOST, PID_RANKS, validate_trace

cfg = get_config("internvl3-2b").reduced().with_(
    family="dense", vlm=None, d_model=64, n_heads=4, kv_heads=2,
    d_ff=256, vocab=512, n_layers=2)
eng = Engine(cfg, ClusterSpec.auto(mem_budget=500.0), seed=0,
             strategy=get_strategy("dhp"))
loader = HeterogeneousLoader("openvid", 16, cfg.vocab, seed=3,
                             max_tokens=450, tokens_per_frame=16)
hist = eng.train(loader=iter(loader), steps=3, lookahead=True,
                 trace=True, report=True)
rep = eng.last_report

tr_obj = None
# trace=True keeps the tracer internal; re-run with an explicit path
from repro.obs import Tracer
tracer = Tracer()
loader = HeterogeneousLoader("openvid", 16, cfg.vocab, seed=4,
                             max_tokens=450, tokens_per_frame=16)
hist2 = eng.train(loader=iter(loader), steps=3, lookahead=True,
                  trace=tracer, report=True)
obj = tracer.to_json()
n = validate_trace(obj)
names = sorted({e["name"] for e in obj["traceEvents"]})
host_tids = {e["tid"] for e in obj["traceEvents"]
             if e["pid"] == PID_HOST and e["ph"] == "X"}
rank_tids = {e["tid"] for e in obj["traceEvents"]
             if e["pid"] == PID_RANKS and e["ph"] == "X"}
rep2 = eng.last_report
print(json.dumps({
    "n_events": n,
    "names": names,
    "n_host_tracks": len(host_tids),
    "rank_tids": sorted(rank_tids),
    "mape": rep2.model_error["mape_pct"],
    "n_samples": rep2.model_error["n_samples"],
    "n_waves": rep2.imbalance["n_waves"],
    "worst_rank": rep2.stragglers["worst_rank"],
    "steps_serialized": len(rep2.steps),
    "first_report_steps": len(rep.steps),
    "model_error_pct": [m.model_error_pct for m in hist2],
    "metrics_keys": sorted(eng.metrics.snapshot())[:4],
}))
eng.close()
""", n_devices=8)
    info = json.loads(out.strip().splitlines()[-1])
    # every instrumented layer shows up in the timeline
    for name in ("microbatch", "pack", "allocate_cost", "allocate_dp",
                 "plan", "run_plan", "collect", "execute"):
        assert name in info["names"], (name, info["names"])
    # main loop + lookahead planner thread = 2 host tracks
    assert info["n_host_tracks"] >= 2
    # per-rank execute spans cover the whole 8-rank cluster
    assert info["rank_tids"] == list(range(8))
    # the run report carries the acceptance analytics
    assert info["n_samples"] > 0
    assert info["n_waves"] >= 1
    assert info["worst_rank"] is not None
    assert info["steps_serialized"] == 3      # StepMetrics embedded
    assert info["first_report_steps"] == 3
    # measuring mode produced a per-step cost-model error signal
    assert any(e > 0 for e in info["model_error_pct"])
    assert info["n_events"] > 0


def test_serving_trace_valid(subproc):
    out = subproc("""
import json
import numpy as np
from repro.api import ClusterSpec, Engine
from repro.obs import Tracer
from repro.obs.trace import validate_trace
from repro.serving.trace import sample_trace

eng = Engine("internvl3-2b", ClusterSpec.auto(), reduced=True, seed=0)
rng = np.random.default_rng(0)
reqs = sample_trace("openvid", 3, rng, vocab=eng.cfg.vocab,
                    max_prompt=64, mean_new_tokens=4, max_new_tokens=6)
srv = eng.serving(slots=2, prefill_chunk=32)
tracer = Tracer()
report = srv.run(reqs, trace=tracer)
obj = tracer.to_json()
n = validate_trace(obj)
names = sorted({e["name"] for e in obj["traceEvents"]})
snap = srv.metrics.snapshot()
print(json.dumps({"n": n, "names": names,
                  "decode_steps": snap["serve/decode_steps"],
                  "report_decode": report.n_decode_steps,
                  "kv_hist": snap["serve/kv_occupancy"]["count"]}))
eng.close()
""", n_devices=8)
    info = json.loads(out.strip().splitlines()[-1])
    assert "decode" in info["names"]
    assert "kv_occupancy" in info["names"]
    assert "queue_depth" in info["names"]
    assert any(n.startswith("prefill") for n in info["names"])
    # metrics registry agrees with the ServeReport
    assert info["decode_steps"] == info["report_decode"] > 0
    assert info["kv_hist"] > 0
