"""Plan IR v2: serialization round-trips, structural hashing, invariant
validation, group-reconfiguration deltas, the structural plan cache and
plan replay — property-based over random ragged batches via
tests/_hypothesis_compat.py."""
import dataclasses
import json

import pytest

from _hypothesis_compat import given, settings, st
from repro.api import ReplayStrategy, get_strategy
from repro.core import (CostModel, ExecutionPlan, GroupDelta, GroupPlan,
                        MicroBatchPlan, PlanCache, PlanValidationError,
                        SeqInfo, analytic_coeffs, diff_plans,
                        evaluate_degrees, load_plans, save_plans,
                        static_plan)

CM = CostModel(dataclasses.replace(
    analytic_coeffs(hidden=1024, n_layers=8, n_heads=8, kv_heads=4,
                    ffn=4096, vocab=32000),
    m_ms=0.0, m_token=1.0))
N_RANKS = 8
BUDGET = 2500.0

# strategies whose plans the IR must round-trip (oracle excluded: it is
# measurement-driven; replay is not a planner)
PLANNERS = ("static", "megatron", "deepspeed", "dhp", "dhp-faithful",
            "bruteforce")

lengths_st = st.lists(st.integers(16, 2400), min_size=1, max_size=12)
planner_st = st.sampled_from(PLANNERS)


def _seqs(lengths, base=0):
    return [SeqInfo(length=n, seq_id=base + i)
            for i, n in enumerate(lengths)]


def _plan(name, lengths, base=0):
    return get_strategy(name, plan_cache=False).bind(
        CM, N_RANKS, BUDGET).plan(_seqs(lengths, base))


# ------------------------------------------------------------ round trip
@settings(max_examples=25, deadline=None)
@given(planner_st, lengths_st)
def test_json_round_trip_preserves_structure(name, lengths):
    seqs = _seqs(lengths)
    plan = _plan(name, lengths)
    obj = plan.to_json()
    json.dumps(obj)                       # actually JSON-serializable
    back = ExecutionPlan.from_json(obj)
    assert back.structural_hash() == plan.structural_hash()
    assert back.degree_histogram == plan.degree_histogram
    assert back.strategy_name == plan.strategy_name
    assert back.stage_ms == plan.stage_ms
    back.validate(seqs, n_ranks=N_RANKS, cost_model=CM,
                  mem_budget=BUDGET)
    # rank-slot geometry (executor cursor, delta naming) survives too
    assert (back.group_slots(N_RANKS) == plan.group_slots(N_RANKS))


@settings(max_examples=15, deadline=None)
@given(planner_st, lengths_st)
def test_every_strategy_plan_validates(name, lengths):
    plan = _plan(name, lengths)
    plan.validate(_seqs(lengths), n_ranks=N_RANKS, cost_model=CM,
                  mem_budget=BUDGET)


@settings(max_examples=15, deadline=None)
@given(lengths_st)
def test_dhp_makespan_is_its_own_degree_evaluation(lengths):
    """Every micro-batch's makespan equals the fixed-vector evaluation
    of its own (seqs, degree) assignment under the same cost model."""
    plan = _plan("dhp", lengths)
    by_id = {s.seq_id: s for s in _seqs(lengths)}
    for mb in plan.micro_batches:
        ev = evaluate_degrees(
            [[by_id[i] for i in g.seq_ids] for g in mb.groups],
            [g.degree for g in mb.groups], CM.group_time)
        assert ev.makespan == pytest.approx(mb.makespan, rel=1e-9)


def test_hash_mismatch_detected_on_tampered_file():
    plan = _plan("dhp", [128, 700, 1900])
    obj = plan.to_json()
    obj["micro_batches"][0]["groups"][0]["degree"] += 1
    with pytest.raises(ValueError, match="structural hash mismatch"):
        ExecutionPlan.from_json(obj)


def test_from_json_rejects_future_version():
    with pytest.raises(ValueError, match="newer than supported"):
        ExecutionPlan.from_json({"version": 99, "micro_batches": [],
                                 "total_time_est": 0.0})


# ------------------------------------------------------------ validation
def _manual_plan(groups, degree=1):
    gps = [GroupPlan(list(ids), degree, 0.1, 1) for ids in groups]
    return ExecutionPlan(
        [MicroBatchPlan(gps, 0.1, degree * len(gps))], 0.1, 0.0, 0.0)


def test_validate_catches_duplicate_and_missing_coverage():
    seqs = _seqs([100, 200, 300])
    with pytest.raises(PlanValidationError, match="coverage"):
        _manual_plan([[0, 1], [1]]).validate(seqs)        # dup + missing
    with pytest.raises(PlanValidationError, match="coverage"):
        _manual_plan([[0, 1, 2, 3]]).validate(seqs)       # extra id


def test_validate_catches_wave_oversubscription():
    plan = _manual_plan([[0], [1]], degree=5)             # 10 > 8 ranks
    with pytest.raises(PlanValidationError, match="Eq. 6"):
        plan.validate(_seqs([10, 10]), n_ranks=N_RANKS)


def test_validate_catches_memory_violation():
    plan = _manual_plan([[0]], degree=1)
    with pytest.raises(PlanValidationError, match="Eq. 3"):
        plan.validate(_seqs([5000]), cost_model=CM, mem_budget=100.0)


# ------------------------------------------------------------ deltas
def test_delta_cold_start_and_self_diff():
    plan = _plan("dhp", [128, 400, 900, 1500])
    cold = diff_plans(None, plan, N_RANKS)
    slots = {(s, d) for _, _, s, d in plan.group_slots(N_RANKS)}
    assert set(cold.created) == slots and not cold.reused
    again = diff_plans(plan, plan, N_RANKS)
    assert set(again.reused) == slots
    assert again.n_reconfigured == 0 and not again.released
    rt = GroupDelta.from_json(json.loads(json.dumps(again.to_json())))
    assert rt.reused == again.reused


def test_delta_resize_detected():
    prev = _manual_plan([[0]], degree=2)
    cur = _manual_plan([[0]], degree=4)                  # start 0 resized
    d = diff_plans(prev, cur, N_RANKS)
    assert d.resized == [(0, 4)] and not d.created
    assert d.n_reconfigured == 1


# ------------------------------------------------------------ plan cache
def test_plan_cache_hits_on_recurring_shape_and_remaps_ids():
    strat = get_strategy("dhp").bind(CM, N_RANKS, BUDGET)
    lengths = [128, 400, 900, 1500]
    p1 = strat.plan(_seqs(lengths))
    assert not p1.from_cache
    p2 = strat.plan(_seqs(lengths, base=40))             # new ids, same shape
    assert p2.from_cache and p2.solver_ms == 0.0
    p2.validate(_seqs(lengths, base=40), n_ranks=N_RANKS,
                cost_model=CM, mem_budget=BUDGET)
    assert p2.degree_histogram == p1.degree_histogram
    assert strat.plan_cache.stats["hits"] == 1
    # different shape -> miss
    p3 = strat.plan(_seqs([64, 64]))
    assert not p3.from_cache


def test_plan_cache_rejects_infeasible_remap():
    """Same length bucket, different d_min: the cached plan must NOT be
    served when the new lengths violate Eq. 3 at the cached degrees."""
    cache = PlanCache()
    a, b = _seqs([520]), _seqs([1000])
    cache.store(a, _manual_plan([[0]], degree=1))         # fits 520@600
    assert cache.lookup(a, cost_model=CM, n_ranks=N_RANKS,
                        mem_budget=600.0) is not None
    assert cache.lookup(b, cost_model=CM, n_ranks=N_RANKS,
                        mem_budget=600.0) is None          # 1000 > 600*1


def test_plan_cache_lru_eviction():
    cache = PlanCache(capacity=2)
    for i, n in enumerate((100, 200, 300)):
        cache.store(_seqs([n + i]), _manual_plan([[0]]))
    assert len(cache) == 2


def test_measuring_strategy_disables_cache_by_default():
    assert get_strategy("oracle").plan_cache is None
    assert get_strategy("dhp").plan_cache is not None
    assert get_strategy("dhp", plan_cache=False).plan_cache is None


# ------------------------------------------------------------ persistence
def test_save_load_plans_file_round_trip(tmp_path):
    plans = [_plan("dhp", [128, 700, 1900], base=i * 10)
             for i in range(3)]
    path = tmp_path / "plans.json"
    save_plans(str(path), plans)
    loaded = load_plans(str(path))
    assert [p.structural_hash() for p in loaded] == \
           [p.structural_hash() for p in plans]


def test_replay_strategy_is_structurally_identical():
    lengths = [128, 700, 1900, 300]
    originals = [_plan("dhp", lengths, base=i * 10) for i in range(2)]
    rs = ReplayStrategy(
        plans=[p.to_json() for p in originals]).bind(CM, N_RANKS, BUDGET)
    for i, orig in enumerate(originals):
        replayed = rs.plan(_seqs(lengths, base=i * 10))
        assert replayed.structural_hash() == orig.structural_hash()
        assert (replayed.group_slots(N_RANKS)
                == orig.group_slots(N_RANKS))
    assert len(rs) == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        rs.plan(_seqs(lengths))


def test_replay_rejects_drifted_stream():
    plan = _plan("dhp", [128, 700])
    rs = ReplayStrategy(plans=[plan]).bind(CM, N_RANKS, BUDGET)
    with pytest.raises(PlanValidationError):
        rs.plan(_seqs([128, 700, 900]))                  # extra sequence


# ------------------------------------------------------------ static plan
def test_static_plan_stage_attribution_matches_dhp_keys():
    seqs = _seqs([128, 400, 900, 1500])
    sp = static_plan(seqs, CM, N_RANKS, BUDGET)
    assert sp.strategy_name == "static"
    assert {"microbatch", "pack", "allocate"} <= set(sp.stage_ms)
    assert all(v >= 0.0 for v in sp.stage_ms.values())
    assert sum(sp.stage_ms.values()) == pytest.approx(sp.schedule_ms,
                                                      rel=0.2)


# ------------------------------------------------------------ end to end
def test_save_replay_bit_identical_on_devices(subproc, tmp_path):
    """A trace saved via plan_log replays bit-identically: same
    structural hashes, same rank slots, same executable-pool keys, same
    loss — the --save-plans/--replay-plans acceptance criterion."""
    subproc(f"""
from repro.api import (ClusterSpec, Engine, ReplayStrategy, load_plans,
                       save_plans)

path = {str(tmp_path / "plans.json")!r}
def engine(strategy):
    return Engine("internvl3-2b", ClusterSpec.auto(mem_budget=900.0),
                  strategy=strategy, reduced=True, seed=3)

rec = engine("dhp")
log1 = []
h1 = rec.train(steps=2, dataset="openvid", global_batch=6,
               max_tokens=256, plan_log=log1)
save_plans(path, log1)
keys1 = list(rec.executor.last_exe_keys)

rep = engine(ReplayStrategy(plans=load_plans(path)))
log2 = []
h2 = rep.train(steps=2, dataset="openvid", global_batch=6,
               max_tokens=256, plan_log=log2)
assert [p.structural_hash() for p in log1] == \\
       [p.structural_hash() for p in log2]
assert [p.group_slots(8) for p in log1] == \\
       [p.group_slots(8) for p in log2]
assert keys1 == list(rep.executor.last_exe_keys)
assert abs(h1[0].loss - h2[0].loss) < 1e-5
assert all(m.strategy == "replay" for m in h2)
print("replay ok", keys1)
""", n_devices=8)


# ------------------------------------------------------------ loader
def test_loader_state_round_trip_through_json():
    import numpy as np

    from repro.data.pipeline import HeterogeneousLoader

    ld = HeterogeneousLoader("openvid", 4, 1000, seed=3,
                             max_tokens=512, tokens_per_frame=16)
    next(ld), next(ld)
    snap = json.loads(json.dumps(ld.state()))             # serializable
    want = next(ld)
    ld.set_state(snap)
    assert ld.batch_index == snap["batch_index"]
    got = next(ld)
    assert [s.length for s in got.infos] == \
           [s.length for s in want.infos]
    assert all(np.array_equal(a, b)
               for a, b in zip(got.tokens, want.tokens))
