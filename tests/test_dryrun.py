"""The multi-pod dry-run machinery itself: one real (arch × shape) pair
lowered + compiled on the 512-placeholder-device production mesh in a
subprocess (the full sweep is `python -m repro.launch.dryrun --all`)."""
import json
import os
import subprocess
import sys


def test_dryrun_single_pair_compiles(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)   # dryrun sets its own 512-device flag
    script = f"""
from repro.launch.dryrun import run_pair
rec = run_pair("mamba2-370m", "long_500k", multi_pod=False,
               out_dir={str(tmp_path)!r}, quiet=True)
assert rec["roofline"]["flops_per_device"] > 0
assert rec["roofline"]["t_lower_bound_s"] > 0
print("OK", rec["mesh"], rec["n_devices"])
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert "OK 16x16 256" in r.stdout
    fn = tmp_path / "mamba2-370m__long_500k__16x16.json"
    rec = json.loads(fn.read_text())
    # roofline terms present + the multi-pod JSON schema is stable
    ro = rec["roofline"]
    for k in ("t_compute_s", "t_memory_s", "t_collective_s",
              "bottleneck", "collective_by_kind"):
        assert k in ro, k
    assert rec["useful_flops_ratio"] is None or \
        rec["useful_flops_ratio"] >= 0


def test_dryrun_variant_plumbing(tmp_path):
    """§Perf variants must reach the lowered program: the sort-dispatch
    variant on an MoE arch changes the compiled FLOPs vs einsum."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("XLA_FLAGS", None)
    script = f"""
from repro.launch.dryrun import run_pair
a = run_pair("granite-moe-1b-a400m", "long_500k", multi_pod=False,
             out_dir={str(tmp_path)!r}, quiet=True,
             variant={{"moe_dispatch": "einsum"}}, tag="__e")
b = run_pair("granite-moe-1b-a400m", "long_500k", multi_pod=False,
             out_dir={str(tmp_path)!r}, quiet=True,
             variant={{"moe_dispatch": "sort"}}, tag="__s")
fa = a["roofline"]["flops_per_device"]
fb = b["roofline"]["flops_per_device"]
assert fa != fb, (fa, fb)
print("OK", fa, fb)
"""
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, (r.stdout, r.stderr)
    assert "OK" in r.stdout
