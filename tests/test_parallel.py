"""Ring attention + CP executor: multi-device tests (subprocess with a
forced host-device count so the main pytest process keeps 1 device)."""
import pytest


def test_ring_attention_non_power_of_two(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.parallel.ring_attention import ring_attention
from repro.models.attention import attn_reference

devs = jax.devices()
for d_cp in (3, 5, 6):
    mesh = Mesh(np.array(devs[:d_cp]), ("cp",))
    B,S,H,Hkv,Dh = 2, 30*d_cp, 4, 2, 16
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key,(B,S,H,Dh))
    k = jax.random.normal(jax.random.fold_in(key,1),(B,S,Hkv,Dh))
    v = jax.random.normal(jax.random.fold_in(key,2),(B,S,Hkv,Dh))
    pos = jnp.tile(jnp.arange(S)[None],(B,1))
    fm = shard_map(
        lambda q,k,v,p: ring_attention(q,k,v,p,axis_name="cp"),
        mesh=mesh,
        in_specs=(P(None,"cp"),)*4, out_specs=P(None,"cp"))
    out = fm(q,k,v,pos)
    ref = attn_reference(q,k,v,mode="causal")
    np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)
    print("cp", d_cp, "ok")
""", n_devices=6)
    assert "cp 5 ok" in out


def test_ring_attention_gradients(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.parallel.ring_attention import ring_attention
from repro.models.attention import attn_reference

devs = jax.devices()
mesh = Mesh(np.array(devs[:3]), ("cp",))
B,S,H,Hkv,Dh = 1, 48, 2, 1, 8
key = jax.random.PRNGKey(0)
q = jax.random.normal(key,(B,S,H,Dh))
k = jax.random.normal(jax.random.fold_in(key,1),(B,S,Hkv,Dh))
v = jax.random.normal(jax.random.fold_in(key,2),(B,S,Hkv,Dh))
pos = jnp.tile(jnp.arange(S)[None],(B,1))
fm = shard_map(
    lambda q,k,v,p: ring_attention(q,k,v,p,axis_name="cp"),
    mesh=mesh, in_specs=(P(None,"cp"),)*4, out_specs=P(None,"cp"))
g1 = jax.grad(lambda q,k,v: (fm(q,k,v,pos)**2).sum(), argnums=(0,1,2))(q,k,v)
g2 = jax.grad(lambda q,k,v: (attn_reference(q,k,v,mode="causal")**2).sum(),
              argnums=(0,1,2))(q,k,v)
for a,b in zip(g1,g2):
    np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4)
print("grads ok")
""", n_devices=3)


def test_ring_decode_distributed_softmax(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.parallel.ring_attention import ring_decode_attention
from repro.models.attention import attn_decode
devs = jax.devices()
mesh = Mesh(np.array(devs[:4]), ("cp",))
B,T,H,Hkv,Dh = 2, 64, 4, 2, 16
key = jax.random.PRNGKey(1)
q1 = jax.random.normal(key,(B,1,H,Dh))
kc = jax.random.normal(jax.random.fold_in(key,1),(B,T,Hkv,Dh))
vc = jax.random.normal(jax.random.fold_in(key,2),(B,T,Hkv,Dh))
gm = shard_map(
    lambda q1,kc,vc: ring_decode_attention(
        q1,kc,vc,jnp.full((q1.shape[0],), kc.shape[1]),axis_name="cp"),
    mesh=mesh, in_specs=(P(),P(None,"cp"),P(None,"cp")), out_specs=P())
out = gm(q1,kc,vc)
ref = attn_decode(q1,kc,vc,jnp.full((B,),T))
np.testing.assert_allclose(out, ref, atol=3e-5, rtol=3e-5)
print("ok")
""", n_devices=4)


def test_executor_dynamic_equals_static(subproc):
    """The paper's correctness invariant: dynamic regrouping changes
    WHERE sequences run, not the gradient."""
    subproc("""
import jax, numpy as np, dataclasses
from repro.configs import get_config
from repro.core import CostModel, analytic_coeffs, DHPScheduler
from repro.core.executor import DHPExecutor
from repro.core.scheduler import static_plan
from repro.data.pipeline import HeterogeneousLoader
from repro.models.model import init_params

cfg = get_config("internvl3-2b").reduced().with_(family="dense", vlm=None)
params = init_params(jax.random.PRNGKey(0), cfg)
loader = HeterogeneousLoader("openvid", 12, cfg.vocab, seed=1,
                             max_tokens=512, tokens_per_frame=16)
data = next(iter(loader))
coeffs = dataclasses.replace(
    analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                    n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    ffn=cfg.d_ff, vocab=cfg.vocab),
    m_ms=0.0, m_token=1.0)
cm = CostModel(coeffs)
ex = DHPExecutor(cfg)
plan = DHPScheduler(cm, 8, mem_budget=900.0).schedule(data.infos)
assert any(g.degree > 1 for mb in plan.micro_batches for g in mb.groups)
l_d, g_d = ex.run_plan(params, plan, data)
l_s, g_s = ex.run_plan(params,
                       static_plan(data.infos, cm, 8, 900.0), data)
assert abs(float(l_d) - float(l_s)) < 2e-5
err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
          for a, b in zip(jax.tree.leaves(g_d), jax.tree.leaves(g_s)))
assert err < 1e-4, err
assert ex.pool.stats.mesh_misses > 0
print("equivalence ok", err)
""", n_devices=8)


def test_group_pool_caches_meshes_and_executables():
    import numpy as np
    import jax
    from repro.core.group_pool import GroupPool, pow2_bucket
    pool = GroupPool(jax.devices() * 8, model_axis=1)  # fake 8 replicas
    m1 = pool.mesh_for(0, 2)
    m2 = pool.mesh_for(0, 2)
    assert m1 is m2
    assert pool.stats.mesh_hits == 1
    calls = []
    e1, miss1 = pool.executable_for(("k", 1),
                                    lambda: calls.append(1) or "exe")
    e2, miss2 = pool.executable_for(("k", 1),
                                    lambda: calls.append(1) or "exe")
    assert e1 == e2 and len(calls) == 1
    assert miss1 and not miss2
    assert pow2_bucket(100) == 128
    assert pow2_bucket(128) == 128
    assert pow2_bucket(129) == 256
