"""Serving runtime: KV block allocator invariants, continuous-batching
scheduler admission/join properties under random traces, chunked-prefill
numerics, and greedy-decode parity of the ServingEngine against the
one-shot `greedy_generate` reference across model families."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.api import get_strategy
from repro.api.engine import demo_cost_model
from repro.configs import get_config
from repro.core.scheduler import PlanCache
from repro.core.cost_model import SeqInfo
from repro.serving.kv_cache import (BlockAllocator, KVCacheError,
                                    KVCacheManager, OutOfBlocks)
from repro.serving.scheduler import (DECODE, FINISHED,
                                     ContinuousBatchingScheduler,
                                     ServeRequest)

CFG = get_config("internvl3-2b").reduced()
PLANNER = get_strategy("dhp").bind(demo_cost_model(CFG), 8, 1024.0)


def _requests(specs, max_new=None):
    """specs: list of (prompt_len, max_new)."""
    rng = np.random.default_rng(0)
    return [ServeRequest(
        request_id=i,
        tokens=rng.integers(0, 1024, size=L, dtype=np.int32),
        max_new_tokens=n if max_new is None else max_new)
        for i, (L, n) in enumerate(specs)]


# ------------------------------------------------------- block allocator
def test_allocator_alloc_free_roundtrip():
    a = BlockAllocator(8)
    blocks = a.alloc(5, request_id=1)
    assert len(set(blocks)) == 5 and a.n_free == 3
    a.free(blocks, request_id=1)
    assert a.n_free == 8 and a.n_used == 0
    a.check_conservation()


def test_allocator_double_free_raises():
    a = BlockAllocator(4)
    blocks = a.alloc(2, request_id=7)
    a.free(blocks, request_id=7)
    with pytest.raises(KVCacheError):
        a.free(blocks, request_id=7)


def test_allocator_foreign_free_raises():
    a = BlockAllocator(4)
    b1 = a.alloc(2, request_id=1)
    with pytest.raises(KVCacheError):
        a.free(b1, request_id=2)
    # and the failed free mutated NOTHING (all-or-nothing)
    assert a.n_used == 2
    a.check_conservation()


def test_allocator_failed_free_leaves_state_untouched():
    a = BlockAllocator(4)
    mine = a.alloc(2, request_id=1)
    with pytest.raises(KVCacheError):
        a.free(mine + [99], request_id=1)   # last block is bogus
    assert a.n_used == 2                    # mine[0] was NOT freed
    a.free(mine, request_id=1)              # clean free still works
    assert a.n_free == 4


def test_submit_infeasible_request_fails_fast():
    kv = KVCacheManager(n_slots=2, n_blocks=2, block_size=16)
    sched = ContinuousBatchingScheduler(kv, PLANNER)
    with pytest.raises(ValueError, match="KV blocks"):
        sched.submit(_requests([(100, 32)])[0])
    assert not sched.has_work()             # nothing enqueued


def test_allocator_exhaustion_is_all_or_nothing():
    a = BlockAllocator(4)
    a.alloc(3, request_id=1)
    with pytest.raises(OutOfBlocks):
        a.alloc(2, request_id=2)
    assert a.n_free == 1         # the failed alloc popped nothing
    a.check_conservation()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=20))
def test_allocator_never_leaks_under_random_churn(sizes):
    a = BlockAllocator(16)
    live = {}
    for i, n in enumerate(sizes):
        if a.n_free >= n:
            live[i] = a.alloc(n, request_id=i)
        elif live:
            rid, blocks = live.popitem()
            a.free(blocks, request_id=rid)
        a.check_conservation()
        owned = [b for bl in live.values() for b in bl]
        assert len(owned) == len(set(owned)) == a.n_used
    for rid, blocks in live.items():
        a.free(blocks, request_id=rid)
    assert a.n_free == 16


# ------------------------------------------------------ kv cache manager
def test_kv_manager_admit_release_recycles_slot_and_blocks():
    kv = KVCacheManager(n_slots=2, n_blocks=8, block_size=16)
    s0 = kv.admit(0, n_tokens=40)        # 3 blocks
    s1 = kv.admit(1, n_tokens=16)        # 1 block
    assert s0 != s1
    assert kv.allocator.n_used == 4
    assert not kv.can_admit(1)           # no slot left
    kv.release(0)
    assert kv.n_free_slots == 1 and kv.allocator.n_used == 1
    assert kv.can_admit(64)
    with pytest.raises(KVCacheError):
        kv.release(0)                    # double release


def test_kv_manager_blocks_gate_admission():
    kv = KVCacheManager(n_slots=4, n_blocks=2, block_size=16)
    kv.admit(0, n_tokens=32)             # both blocks
    assert kv.n_free_slots == 3
    assert not kv.can_admit(1)           # slots free, blocks exhausted
    assert kv.occupancy == 1.0


# ------------------------------------- scheduler invariants (host-only)
def _simulate(reqs, *, n_slots, block_size=16, chunk=8):
    """Pure-host lifecycle simulation; returns the scheduler + stats."""
    max_ctx = max(r.context_len for r in reqs)
    n_blocks = n_slots * -(-max_ctx // block_size)
    kv = KVCacheManager(n_slots, n_blocks, block_size)
    sched = ContinuousBatchingScheduler(kv, PLANNER,
                                        prefill_chunk=chunk)
    for r in reqs:
        sched.submit(r)
    admitted_order = []
    iters = 0
    while sched.has_work():
        iters += 1
        assert iters < 10_000, "scheduler did not converge"
        it = sched.step()
        admitted_order.extend(it.admitted)
        # -- invariants every iteration ------------------------------
        active_slots = [s.slot for s in sched.active]
        assert len(active_slots) == len(set(active_slots)), \
            "decode slot double-assigned"
        kv.allocator.check_conservation()
        chunk_ids = [c.request_id for g in it.prefill_groups
                     for c in g.chunks]
        assert len(chunk_ids) == len(set(chunk_ids)), \
            "request prefilled twice in one iteration"
        if it.plan is not None:
            planned = sorted(i for mb in it.plan.micro_batches
                             for g in mb.groups for i in g.seq_ids)
            assert planned == sorted(chunk_ids)
        # -- fake execution ------------------------------------------
        for g in it.prefill_groups:
            for c in g.chunks:
                sched.mark_prefilled(c.request_id, c.length)
        for rid in it.decode_ids:
            stt = sched.states[rid]
            stt.generated.append(0)
            if len(stt.generated) >= stt.request.max_new_tokens:
                sched.finish(rid, float(iters))
    return sched, admitted_order


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 90), min_size=1, max_size=10),
       st.integers(1, 4),
       st.sampled_from([4, 8, 1 << 30]))
def test_scheduler_random_trace_invariants(lens, n_slots, chunk):
    reqs = _requests([(L, 1 + L % 5) for L in lens])
    sched, admitted = _simulate(reqs, n_slots=n_slots, chunk=chunk)
    # everyone finished, exactly once, FIFO admission order
    assert admitted == [r.request_id for r in reqs]
    assert all(s.status == FINISHED for s in sched.states.values())
    assert all(len(s.generated) == s.request.max_new_tokens
               for s in sched.states.values())
    # every slot and block returned
    assert sched.kv.n_free_slots == n_slots
    assert sched.kv.allocator.n_used == 0


def test_scheduler_chunked_prefill_progress():
    """A long prompt takes ceil((L-1)/chunk) prefill iterations and its
    chunk lengths tile the prompt exactly."""
    reqs = _requests([(50, 2)])
    kv = KVCacheManager(1, 16, 16)
    sched = ContinuousBatchingScheduler(kv, PLANNER, prefill_chunk=16)
    sched.submit(reqs[0])
    seen = []
    for _ in range(4):
        it = sched.step()
        for g in it.prefill_groups:
            for c in g.chunks:
                assert c.start == sum(x[1] for x in seen)
                seen.append((c.start, c.length))
                sched.mark_prefilled(c.request_id, c.length)
    assert [ln for _, ln in seen] == [16, 16, 16, 1]   # covers 49 = L-1
    assert sched.states[0].status == DECODE


def test_plan_cache_salt_partitions_key_space():
    seqs = [SeqInfo(length=64, seq_id=0), SeqInfo(length=32, seq_id=1)]
    plan = get_strategy("dhp", plan_cache=False).bind(
        demo_cost_model(CFG), 8, 1024.0).plan(seqs)
    train_cache = PlanCache(salt="train")
    train_cache.store(seqs, plan)
    serve_cache = PlanCache(salt="serve-prefill")
    serve_cache._entries = train_cache._entries     # shared backing
    assert serve_cache.lookup(seqs) is None         # salt mismatch
    assert train_cache.lookup(seqs) is not None


# ----------------------------------------------- engine-level (jit) ----
def _reference_stream(eng, prompt, n):
    """Token-id stream the one-shot Engine.serve path produces for one
    request, aligned with the runtime's convention (first generated
    token included)."""
    import jax
    import jax.numpy as jnp

    from repro.models.model import init_cache, prefill, prefill_cross_kv
    from repro.serving.serve_step import greedy_generate
    cfg = eng.cfg
    toks = jnp.asarray(prompt)[None]
    L = len(prompt)
    cache_len = L + n + 1
    if cfg.family in ("dense", "moe", "vlm"):
        logits, cache = prefill(eng.state.params, cfg,
                                {"tokens": toks}, cache_len=cache_len)
        first = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
        out, _ = greedy_generate(eng.state.params, cfg, cache, first,
                                 n - 1)
        return [int(first[0])] + [int(t) for t in out[0]]
    cache = init_cache(cfg, 1, cache_len)
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(eng.seed + 2),
            (1, cfg.encdec.n_audio_frames, cfg.d_model))
        cache = prefill_cross_kv(eng.state.params, cfg, frames, cache)
    first = toks[:, -1].astype(jnp.int32)
    out, _ = greedy_generate(eng.state.params, cfg, cache, first, n)
    return [int(t) for t in out[0]]


# one arch per family the ISSUE names; dense runs with a small chunk so
# the trace exercises chunked + batched-one-shot + single-token paths
PARITY_CASES = [("internvl3-2b", 8), ("olmoe-1b-7b", 64),
                ("mamba2-370m", 64), ("whisper-small", 64)]


@pytest.mark.parametrize("arch,chunk", PARITY_CASES)
def test_decode_parity_with_greedy_generate(arch, chunk):
    from repro.api import Engine
    eng = Engine(arch, strategy="dhp", reduced=True, seed=0)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, eng.cfg.vocab, size=L, dtype=np.int32)
               for L in (21, 5, 1)]
    n_new = 4
    trace = [ServeRequest(request_id=i, tokens=p, max_new_tokens=n_new)
             for i, p in enumerate(prompts)]
    srv = eng.serving(slots=2, prefill_chunk=chunk)
    rep = srv.run(trace)
    assert len(rep.requests) == len(trace)
    for m in rep.requests:
        ref = _reference_stream(eng, prompts[m.request_id], n_new)
        assert m.tokens == ref, (
            f"{arch} request {m.request_id}: serving stream {m.tokens} "
            f"!= greedy_generate reference {ref}")
        assert m.ttft_s is not None and m.ttft_s >= 0
    # runtime accounting: everything joined, nothing leaked
    assert rep.total_tokens == n_new * len(trace)
    assert max(rep.kv_occupancy) <= 1.0


def test_chunked_prefill_matches_one_shot_cache():
    import jax
    import jax.numpy as jnp

    from repro.models.model import (init_cache, init_params, prefill,
                                    prefill_chunk)
    cfg = CFG.with_(family="dense", vlm=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    L, T = 36, 64
    toks = rng.integers(0, cfg.vocab, size=(1, L)).astype(np.int32)
    _, ref = prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                     cache_len=T)
    cache = init_cache(cfg, 1, T)
    for s, c in [(0, 16), (16, 16), (32, 4)]:
        cache = prefill_chunk(params, cfg, cache,
                              jnp.asarray(toks[:, s:s + c]), s)
    np.testing.assert_allclose(np.asarray(cache["k"][:, :, :L]),
                               np.asarray(ref["k"][:, :, :L]),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(cache["v"][:, :, :L]),
                               np.asarray(ref["v"][:, :, :L]),
                               atol=1e-4)


def test_serving_executables_reused_across_traces():
    """Second trace with the same bucketed shapes compiles nothing —
    the continuous-batching promise that batch composition changes
    never re-jit."""
    from repro.api import Engine
    eng = Engine("internvl3-2b", strategy="dhp", reduced=True, seed=0)
    rng = np.random.default_rng(3)

    def trace(base):
        return [ServeRequest(request_id=i,
                             tokens=rng.integers(0, eng.cfg.vocab,
                                                 size=L,
                                                 dtype=np.int32),
                             max_new_tokens=3)
                for i, L in enumerate((17, 4, 9))]

    srv = eng.serving(slots=2, prefill_chunk=64)
    first = srv.run(trace(0))
    assert first.exe_misses > 0
    second = srv.run(trace(100))
    assert second.exe_misses == 0, (
        f"steady-state serving recompiled {second.exe_misses} "
        f"executables")
    assert second.plan_cache.get("hits", 0) > 0


def test_decode_shape_bucketing():
    from repro.api import ClusterSpec
    spec = ClusterSpec.auto()
    assert spec.decode_shape(3, 100) == (4, 128)
    assert spec.decode_shape(1, 1)[0] == 2
    s1 = spec.decode_shape(5, 300)
    s2 = spec.decode_shape(6, 290)
    assert s1 == s2               # same rung -> same executable key
