"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention_flat
from repro.kernels.ops import flash_attention
from repro.kernels.ref import flash_attention_ref, rglru_scan_ref
from repro.kernels.rglru_scan import rglru_scan_pallas

KEY = jax.random.PRNGKey(0)


def qkv(B, S, H, Hkv, D, dtype=jnp.float32, Skv=None):
    Skv = Skv or S
    q = jax.random.normal(KEY, (B, S, H, D), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Skv, Hkv, D),
                          dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Skv, Hkv, D),
                          dtype)
    return q, k, v


# ------------------------------------------------------------- flash attn
@pytest.mark.parametrize("mode,window", [("causal", None), ("full", None),
                                         ("sliding", 96)])
@pytest.mark.parametrize("S,D,bq,bk", [(128, 64, 64, 64),
                                       (256, 64, 128, 64),
                                       (192, 32, 64, 128)])
def test_flash_shape_sweep(mode, window, S, D, bq, bk):
    q, k, v = qkv(1, S, 2, 1, D)
    out = flash_attention(q, k, v, mode=mode, window=window,
                          block_q=bq, block_k=bk)
    ref = flash_attention(q, k, v, mode=mode, window=window, ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_dtype_sweep(dtype, tol):
    q, k, v = qkv(2, 128, 4, 2, 64, dtype)
    out = flash_attention(q, k, v, mode="causal")
    ref = flash_attention(q, k, v, mode="causal", ref=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_flash_unaligned_lengths_padded():
    """Sq/Sk not multiples of the block — the wrapper pads + masks."""
    q, k, v = qkv(1, 100, 2, 2, 32, Skv=100)
    out = flash_attention(q, k, v, mode="causal", block_q=64, block_k=64)
    ref = flash_attention(q, k, v, mode="causal", ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_kv_offset_ring_hop():
    """kv_offset makes the kernel compute one ring-attention hop: local
    queries vs a KV block owned by another rank."""
    B, S, D = 1, 128, 32
    q = jax.random.normal(KEY, (B, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, D))
    # Hop where the incoming KV block is entirely in the PAST: queries at
    # global [128, 256), kv at [0, 128) -> kv_offset = 0 - 128 = -128.
    # Every kv position is attendable, so one hop == full softmax over
    # this block.
    out = flash_attention_flat(q, k, v, mode="causal",
                               block_q=64, block_k=64, kv_offset=-128)
    s = (np.asarray(q[0], np.float64) @ np.asarray(k[0], np.float64).T
         / np.sqrt(D))
    p = np.exp(s - s.max(-1, keepdims=True))
    ref = (p / p.sum(-1, keepdims=True)) @ np.asarray(v[0], np.float64)
    np.testing.assert_allclose(np.asarray(out[0], np.float64), ref,
                               atol=1e-4, rtol=1e-4)

    # Hop where the incoming KV block is entirely in the FUTURE: queries
    # at [0, 128), kv at [128, 256) -> kv_offset = +128. Nothing is
    # attendable under the causal mask; the l=0 guard emits zeros.
    out_f = flash_attention_flat(q, k, v, mode="causal",
                                 block_q=64, block_k=64, kv_offset=128)
    np.testing.assert_allclose(np.asarray(out_f), 0.0, atol=0.0)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 96, 160]),
       st.sampled_from([32, 64]))
def test_flash_property_random_shapes(B, S, D):
    q, k, v = qkv(B, S, 2, 2, D)
    out = flash_attention(q, k, v, mode="causal", block_q=64, block_k=64)
    ref = flash_attention(q, k, v, mode="causal", ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ------------------------------------------------------------- rglru scan
@pytest.mark.parametrize("S,W,chunk", [(64, 32, 16), (100, 16, 32),
                                       (128, 128, 64)])
def test_rglru_scan_sweep(S, W, chunk):
    a = jax.random.uniform(KEY, (2, S, W), minval=0.3, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, W)) * 0.1
    out = rglru_scan_pallas(a, b, chunk=chunk)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_rglru_scan_dtype_bf16():
    a = jax.random.uniform(KEY, (1, 64, 32), minval=0.5,
                           maxval=0.95).astype(jnp.bfloat16)
    b = (jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 32))
         * 0.1).astype(jnp.bfloat16)
    out = rglru_scan_pallas(a, b, chunk=32)
    ref = rglru_scan_ref(a.astype(jnp.float32), b.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=5e-2, rtol=5e-2)


# ------------------------------------------------------------- ssd chunk
def _ssd_inputs(G, c, N, P, dtype=jnp.float32, key=7):
    k = jax.random.fold_in(KEY, key)
    ks = jax.random.split(k, 5)
    C = jax.random.normal(ks[0], (G, c, N), dtype) * 0.3
    B = jax.random.normal(ks[1], (G, c, N), dtype) * 0.3
    x = jax.random.normal(ks[2], (G, c, P), dtype)
    # da = dt*A with A<0: keep decays in a numerically sane range
    dt = jax.nn.softplus(jax.random.normal(ks[3], (G, c))) + 1e-3
    da = -dt * jax.random.uniform(ks[4], (G, c), minval=0.05, maxval=1.0)
    return C, B, x, da.astype(dtype), dt.astype(dtype)


@pytest.mark.parametrize("G,c,N,P", [(3, 64, 32, 16), (2, 128, 128, 64),
                                     (1, 128, 64, 128), (4, 32, 16, 8)])
def test_ssd_chunk_shape_sweep(G, c, N, P):
    from repro.kernels.ref import ssd_chunk_ref
    from repro.kernels.ssd_chunk import ssd_chunk_pallas
    C, B, x, da, dt = _ssd_inputs(G, c, N, P)
    y, st, cum = ssd_chunk_pallas(C, B, x, da, dt)
    yr, str_, cumr = ssd_chunk_ref(C, B, x, da, dt)
    np.testing.assert_allclose(np.asarray(cum), np.asarray(cumr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(str_),
                               atol=1e-4, rtol=1e-4)


def test_ssd_chunk_dtype_bf16():
    from repro.kernels.ref import ssd_chunk_ref
    from repro.kernels.ssd_chunk import ssd_chunk_pallas
    C, B, x, da, dt = _ssd_inputs(2, 64, 32, 16, dtype=jnp.bfloat16)
    y, st, _ = ssd_chunk_pallas(C, B, x, da, dt)
    yr, str_, _ = ssd_chunk_ref(C, B, x, da, dt)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(st, np.float32),
                               np.asarray(str_, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_ssd_chunk_scan_matches_model_ssd():
    """The composed kernel op (intra Pallas + inter scan) must equal the
    models/ssm.py chunked-SSD core on a full multi-chunk sequence."""
    from repro.kernels.ops import ssd_chunk_scan
    Bsz, S, H, P, N, c = 2, 96, 2, 8, 16, 32
    nc, G = S // c, Bsz * H
    k = jax.random.fold_in(KEY, 11)
    ks = jax.random.split(k, 5)
    Cm = jax.random.normal(ks[0], (Bsz, S, N)) * 0.3
    Bm = jax.random.normal(ks[1], (Bsz, S, N)) * 0.3
    xh = jax.random.normal(ks[2], (Bsz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bsz, S, H))) + 1e-3
    A = -jax.random.uniform(ks[4], (H,), minval=0.1, maxval=1.0)

    # oracle: the per-head path from models/ssm.py (sequential scan)
    def seq_ref(b, h):
        hstate = jnp.zeros((N, P))
        ys = []
        for t in range(S):
            a_t = jnp.exp(dt[b, t, h] * A[h])
            hstate = a_t * hstate + dt[b, t, h] * jnp.outer(
                Bm[b, t], xh[b, t, h])
            ys.append(Cm[b, t] @ hstate)
        return jnp.stack(ys)

    # kernel path: [G, nc, c, ...] layout, da = dt*A per head
    def to_g(t):           # [B,S,...] with head -> [G,nc,c,...]
        return t.reshape(Bsz, nc, c, *t.shape[2:])
    Cg = jnp.broadcast_to(to_g(Cm)[:, None], (Bsz, H, nc, c, N)).reshape(
        G, nc, c, N)
    Bg = jnp.broadcast_to(to_g(Bm)[:, None], (Bsz, H, nc, c, N)).reshape(
        G, nc, c, N)
    xg = xh.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, c, P).reshape(
        G, nc, c, P)
    dtg = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, c).reshape(G, nc, c)
    dag = dtg * jnp.repeat(A, Bsz * nc * c).reshape(
        H, Bsz, nc, c).transpose(1, 0, 2, 3).reshape(G, nc, c)
    y = ssd_chunk_scan(Cg, Bg, xg, dag, dtg)
    y = y.reshape(Bsz, H, S, P)
    for b in range(Bsz):
        for h in range(H):
            np.testing.assert_allclose(np.asarray(y[b, h]),
                                       np.asarray(seq_ref(b, h)),
                                       atol=1e-4, rtol=1e-4)


def test_ssm_forward_pallas_impl_matches_jnp():
    """models/ssm.py with impl='pallas' (ssd_chunk kernel) must equal the
    portable jnp path end-to-end through the full Mamba-2 block."""
    from repro.models.ssm import init_ssm, ssm_forward
    D, dS, hd, ex, chunk = 32, 16, 8, 2, 16
    params = init_ssm(jax.random.fold_in(KEY, 21), D, d_state=dS,
                      head_dim=hd, expand=ex, conv_width=4,
                      dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 22), (2, 40, D)) * 0.5
    y_jnp = ssm_forward(params, x, d_state=dS, head_dim=hd, expand=ex,
                        chunk=chunk, impl="jnp")
    y_pl = ssm_forward(params, x, d_state=dS, head_dim=hd, expand=ex,
                       chunk=chunk, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_jnp),
                               atol=2e-4, rtol=2e-4)
