"""The unified `repro.api` engine: strategy registry round-trip,
Engine.plan/execute smoke on the 8-host-device CPU demo mesh, the
OracleStrategy measured-cost loop, and the backward-compat import
surface."""
import dataclasses

import numpy as np
import pytest

from repro.api import (ClusterSpec, BruteForceStrategy, DHPStrategy,
                       Engine, MeasuredCostModel, OracleStrategy,
                       Session, StaticStrategy, Strategy,
                       available_strategies, demo_cost_model,
                       get_strategy, register_strategy)
from repro.core import CostModel, SeqInfo, analytic_coeffs

CM = CostModel(dataclasses.replace(
    analytic_coeffs(hidden=1024, n_layers=8, n_heads=8, kv_heads=4,
                    ffn=4096, vocab=32000),
    m_ms=0.0, m_token=1.0))


def _seqs(lengths):
    return [SeqInfo(length=n, seq_id=i) for i, n in enumerate(lengths)]


# ------------------------------------------------------------ registry
def test_registry_round_trip():
    expected = {"static": StaticStrategy, "megatron": StaticStrategy,
                "deepspeed": StaticStrategy, "dhp": DHPStrategy,
                "dhp-faithful": DHPStrategy,
                "bruteforce": BruteForceStrategy,
                "oracle": OracleStrategy}
    assert set(expected) <= set(available_strategies())
    for name, cls in expected.items():
        strat = get_strategy(name)
        assert isinstance(strat, cls), name
        assert strat.name == name
        assert not strat.is_bound


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown strategy"):
        get_strategy("nope")


def test_registry_defaults_and_overrides():
    assert get_strategy("deepspeed").power_of_two is True
    assert get_strategy("megatron").power_of_two is False
    assert get_strategy("static", degree=4).degree == 4
    faithful = get_strategy("dhp-faithful")
    assert faithful.options["balance_packing"] is False
    assert faithful.options["serial_fallback"] is False


def test_register_new_strategy_is_one_entry():
    @register_strategy("all-ones-test")
    class AllOnes(Strategy):
        def _plan(self, seqs):
            from repro.core.scheduler import (ExecutionPlan, GroupPlan,
                                              MicroBatchPlan)
            groups = [GroupPlan([s.seq_id], 1,
                                self.cm.group_time([s], 1), s.length)
                      for s in seqs]
            mk = max(g.est_time for g in groups)
            return ExecutionPlan([MicroBatchPlan(groups, mk, len(groups))],
                                 mk, 0.0, 0.0)

    strat = get_strategy("all-ones-test").bind(CM, 8, 1e4)
    plan = strat.plan(_seqs([100, 200]))
    assert plan.strategy_name == "all-ones-test"
    assert plan.degree_histogram == {1: 2}


# ------------------------------------------------------------ planning
def test_every_builtin_strategy_plans_and_is_attributed():
    seqs = _seqs([128, 400, 900, 1500, 300, 64])
    for name in available_strategies():
        if name in ("oracle", "all-ones-test"):
            continue
        plan = get_strategy(name).bind(CM, 8, 2000.0).plan(seqs)
        assert plan.strategy_name == name
        scheduled = {i for mb in plan.micro_batches for g in mb.groups
                     for i in g.seq_ids}
        assert scheduled == {s.seq_id for s in seqs}, name
        assert plan.stage_ms, name


def test_dhp_stage_timings_cover_pipeline():
    plan = get_strategy("dhp").bind(CM, 8, 2000.0).plan(
        _seqs([128, 400, 900, 1500]))
    assert {"microbatch", "pack", "allocate"} <= set(plan.stage_ms)
    assert all(v >= 0.0 for v in plan.stage_ms.values())


def test_bruteforce_is_exact_lower_bound_on_makespan():
    """The exhaustive Stage-2 solver can never produce a worse makespan
    than the DP on the same packing."""
    seqs = _seqs([500, 1200, 800])
    dp = get_strategy("dhp", serial_fallback=False).bind(
        CM, 6, 1500.0).plan(seqs)
    bf = get_strategy("bruteforce").bind(CM, 6, 1500.0).plan(seqs)
    assert bf.total_time_est <= dp.total_time_est * (1 + 1e-9)


def test_async_prepare_collect_uniform_across_strategies():
    seqs = _seqs([128, 700, 2100])
    for name in ("static", "dhp"):
        strat = get_strategy(name).bind(CM, 8, 2500.0)
        strat.prepare(seqs)
        plan = strat.collect()
        assert plan.strategy_name == name
        with pytest.raises(RuntimeError):
            strat.collect()        # second collect without prepare
        strat.close()


def test_unbound_strategy_raises():
    with pytest.raises(RuntimeError, match="unbound"):
        get_strategy("dhp").plan(_seqs([100]))


# ------------------------------------------------------------ oracle
def test_measured_cost_model_prefers_measurements():
    mcm = MeasuredCostModel(CM)
    seqs = _seqs([1000])
    est = CM.group_time(seqs, 2)
    assert mcm.group_time(seqs, 2) == pytest.approx(est)
    mcm.record(tokens=1000, degree=2, seconds=42.0)
    assert mcm.group_time(seqs, 2) == pytest.approx(42.0)
    # unmeasured shapes get the calibration-scaled analytic estimate
    other = _seqs([8000])
    scaled = mcm.group_time(other, 4)
    assert scaled == pytest.approx(
        CM.group_time(other, 4) * (42.0 / CM.group_time(seqs, 2)))


def test_oracle_observe_skips_compile_tainted_samples():
    strat = get_strategy("oracle").bind(CM, 8, 2000.0)
    strat.observe(None, [
        {"tokens": 500, "degree": 1, "seconds": 9.0, "compiled": True},
        {"tokens": 500, "degree": 1, "seconds": 0.5, "compiled": False},
    ])
    assert strat.measured.n_samples == 1
    assert strat.measured.group_time(_seqs([500]), 1) == pytest.approx(0.5)


def test_oracle_plan_cost_evaluates_any_plan():
    strat = get_strategy("oracle").bind(CM, 8, 2000.0)
    seqs = _seqs([300, 900])
    static = get_strategy("static").bind(CM, 8, 2000.0).plan(seqs)
    cost = strat.plan_cost(static, seqs)
    assert cost > 0


# ------------------------------------------------------------ engine
def test_engine_plan_host_side():
    """Planning needs no multi-device mesh — runs in-process."""
    eng = Engine("internvl3-2b", ClusterSpec.auto(mem_budget=900.0),
                 strategy="dhp", reduced=True)
    from repro.data.pipeline import HeterogeneousLoader
    data = next(iter(HeterogeneousLoader(
        "openvid", 8, eng.cfg.vocab, seed=2, max_tokens=512,
        tokens_per_frame=16)))
    plan = eng.plan(data)
    assert plan.strategy_name == "dhp"
    assert plan.n_groups >= 1
    assert eng.cfg.family == "dense"       # vlm normalised to tokens
    assert Session is Engine


def test_engine_train_execute_smoke_8_devices(subproc):
    """Engine.plan/execute/train on the 8-host-device CPU demo mesh:
    dhp and static run through the SAME loop; oracle learns
    measurements."""
    subproc("""
from repro.api import ClusterSpec, Engine
cluster = ClusterSpec.auto(mem_budget=900.0)

eng = Engine("internvl3-2b", cluster, strategy="dhp", reduced=True,
             seed=3)
hist = eng.train(steps=4, dataset="openvid", global_batch=12,
                 max_tokens=512)
assert len(hist) == 4
assert all(m.strategy == "dhp" for m in hist)
degrees = set()
for m in hist:
    degrees.update(m.degree_histogram)
assert len(degrees) >= 2, degrees          # heterogeneous CP degrees
assert hist[-1].loss < hist[0].loss + 0.5  # sane loss trajectory

static = Engine("internvl3-2b", cluster, strategy="static",
                reduced=True, seed=3)
h2 = static.train(steps=2, dataset="openvid", global_batch=12,
                  max_tokens=512)
assert all(m.strategy == "static" for m in h2)

oracle = Engine("internvl3-2b", cluster, strategy="oracle",
                reduced=True, seed=3)
h3 = oracle.train(steps=3, dataset="openvid", global_batch=8,
                  max_tokens=512)
assert oracle.strategy.measured.n_samples > 0
print("ok", hist[0].loss, "->", hist[-1].loss,
      "oracle samples", oracle.strategy.measured.n_samples)
""", n_devices=8)


# ------------------------------------------------------------ compat
def test_backward_compat_core_import_surface():
    from repro.core import (Allocation, AtomicGroup, CostCoeffs,  # noqa
                            CostModel, DHPScheduler, ExecutionPlan,
                            GroupPlan, Hardware, MicroBatchPlan,
                            Profiler, SeqInfo, allocate,
                            allocate_bruteforce, analytic_coeffs,
                            pack_sequences, static_plan)
    # pre-API positional construction still works (new fields default)
    plan = ExecutionPlan([], 0.0, 0.0, 0.0)
    assert plan.strategy_name == "" and plan.stage_ms == {}


def test_backward_compat_launch_train_shims():
    from repro.launch.train import (build_parser, main,  # noqa: F401
                                    run_dhp, run_static)
    args = build_parser().parse_args(["--mode", "dhp", "--steps", "1"])
    assert (args.strategy or args.mode) == "dhp"


def test_cli_list_strategies(capsys):
    from repro.api.cli import main
    main(["--list-strategies"])
    out = capsys.readouterr().out.split()
    for name in ("static", "dhp", "bruteforce", "oracle"):
        assert name in out
