"""Per-architecture smoke tests (reduced variants, one fwd/train step on
CPU asserting output shapes + no NaNs) + model-level equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, get_config
from repro.models.model import (decode_step, forward, init_cache,
                                init_params, prefill, prefill_cross_kv)
from repro.training.optimizer import AdamW
from repro.training.train_step import TrainState, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, with_labels=True, seq=S):
    batch = {"tokens": jax.random.randint(KEY, (B, seq), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    if cfg.family == "vlm":
        P = max(1, seq // 4)
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, P, cfg.vlm.vision_dim))
        batch["patch_pos"] = jnp.tile(jnp.arange(P)[None], (B, 1))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encdec.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    params = init_params(KEY, cfg)
    logits, aux = forward(params, cfg, make_batch(cfg, False))
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    """One real train step on CPU: loss finite, params change."""
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    opt = AdamW(lr=1e-3)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, make_batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    before = jax.tree.leaves(params)[0]
    after = jax.tree.leaves(state2.params)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    cache = init_cache(cfg, B, 64)
    if cfg.family == "audio":
        cache = prefill_cross_kv(
            params, cfg, make_batch(cfg, False)["frames"], cache)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache2 = decode_step(params, cfg, cache, tok)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("arch", ["glm4-9b", "granite-moe-1b-a400m",
                                  "pixtral-12b"])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        # drop-free capacity: token dropping legitimately differs between
        # a decode micro-batch (B tokens) and a full forward (B*S tokens)
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    batch = make_batch(cfg, False)
    batch["tokens"] = toks[:, :S]
    _, cache = prefill(params, cfg, batch, cache_len=S + 4)
    lg, _ = decode_step(params, cfg, cache, toks[:, S])
    full = dict(batch)
    full["tokens"] = toks
    if cfg.family == "vlm":   # patch positions still valid (< S)
        pass
    ref, _ = forward(params, cfg, full)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                               atol=5e-5, rtol=5e-5)


def test_sliding_window_decode_matches_sliding_forward():
    cfg = get_config("glm4-9b").reduced().with_(sliding_window=16)
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    _, cache = prefill(params, cfg, {"tokens": toks[:, :S]})
    assert cache["k"].shape[2] == 16       # ring buffer = window
    lg, _ = decode_step(params, cfg, cache, toks[:, S])
    ref, _ = forward(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref[:, -1]),
                               atol=5e-5, rtol=5e-5)


def test_ssm_decode_equals_chunked_scan():
    cfg = get_config("mamba2-370m").reduced()
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, 20), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, B, 32)
    outs = []
    for t in range(20):
        lg, cache = decode_step(params, cfg, cache, toks[:, t])
        outs.append(lg)
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_hybrid_decode_equals_forward():
    cfg = get_config("recurrentgemma-2b").reduced()
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, 20), 0, cfg.vocab)
    full, _ = forward(params, cfg, {"tokens": toks})
    cache = init_cache(cfg, B, 64)
    outs = []
    for t in range(20):
        lg, cache = decode_step(params, cfg, cache, toks[:, t])
        outs.append(lg)
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_audio_decode_consistency():
    cfg = get_config("whisper-small").reduced()
    params = init_params(KEY, cfg)
    batch = make_batch(cfg, False, seq=12)
    full, _ = forward(params, cfg, batch)
    cache = init_cache(cfg, B, 32)
    cache = prefill_cross_kv(params, cfg, batch["frames"], cache)
    outs = []
    for t in range(12):
        lg, cache = decode_step(params, cfg, cache, batch["tokens"][:, t])
        outs.append(lg)
    seq = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(full),
                               atol=2e-3, rtol=2e-3)


def test_moe_sort_dispatch_equals_einsum():
    """The O(T·k·D) sort-based dispatch must reproduce the one-hot
    einsum reference exactly (same capacity-queue semantics), for
    values AND gradients."""
    from repro.models.moe import init_moe, moe_ffn
    D, E, F, k = 16, 8, 32, 2
    params = init_moe(KEY, D, E, F, jnp.float32)
    for T, cf in ((64, 1.25), (64, 0.5), (16, 2.0)):
        x = jax.random.normal(jax.random.fold_in(KEY, T), (2, T // 2, D))

        def run(disp, x=x, cf=cf):
            out, aux = moe_ffn(params, x, top_k=k, capacity_factor=cf,
                               dispatch=disp)
            return out, aux

        o_e, a_e = run("einsum")
        o_s, a_s = run("sort")
        np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_e),
                                   atol=1e-5, rtol=1e-5)
        assert float(a_e) == pytest.approx(float(a_s), rel=1e-6)

        g_e = jax.grad(lambda x: run("einsum", x)[0].sum())(x)
        g_s = jax.grad(lambda x: run("sort", x)[0].sum())(x)
        np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_e),
                                   atol=1e-5, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6).map(lambda e: 2 ** e),          # experts 4..64
       st.integers(1, 4),                                 # top_k
       st.sampled_from([0.5, 1.0, 1.25, 2.0]),            # capacity
       st.integers(2, 6))                                 # tokens/8
def test_moe_sort_dispatch_property(E, k, cf, t8):
    """Property: sort dispatch == einsum dispatch for random
    (experts, top_k, capacity, tokens) combinations."""
    from repro.models.moe import init_moe, moe_ffn
    k = min(k, E)
    D, F = 8, 16
    params = init_moe(jax.random.PRNGKey(E * 7 + k), D, E, F, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(t8), (1, 8 * t8, D))
    o_e, a_e = moe_ffn(params, x, top_k=k, capacity_factor=cf,
                       dispatch="einsum")
    o_s, a_s = moe_ffn(params, x, top_k=k, capacity_factor=cf,
                       dispatch="sort")
    np.testing.assert_allclose(np.asarray(o_s), np.asarray(o_e),
                               atol=1e-5, rtol=1e-5)
    assert float(a_e) == pytest.approx(float(a_s), rel=1e-6)


def test_moe_grouped_sort_dispatch_no_drop_equivalence():
    """With capacity that never binds, shard-local grouped dispatch is
    numerically identical to the global einsum reference."""
    from repro.models.moe import init_moe, moe_ffn
    D, E, F, k = 16, 4, 32, 2
    params = init_moe(KEY, D, E, F, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (4, 32, D))
    o_e, _ = moe_ffn(params, x, top_k=k, capacity_factor=float(E),
                     dispatch="einsum")
    o_g, _ = moe_ffn(params, x, top_k=k, capacity_factor=float(E),
                     dispatch="sort", dispatch_group=16)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_e),
                               atol=1e-5, rtol=1e-5)


def test_grad_accumulation_equivalence():
    """accum_steps=4 must give the same update as one full batch."""
    cfg = get_config("glm4-9b").reduced()
    params = init_params(KEY, cfg)
    opt = AdamW(lr=1e-3)
    batch = {"tokens": jax.random.randint(KEY, (8, 16), 0, cfg.vocab)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    s1, m1 = make_train_step(cfg, opt)(
        TrainState(params, opt.init(params)), batch)
    s4, m4 = make_train_step(cfg, opt, accum_steps=4)(
        TrainState(params, opt.init(params)), batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s4.params)):
        # fp32 accumulation-order noise is amplified by Adam's rescaling
        # where the raw gradient is ~0, hence the loose atol.
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-4)
