"""Packed variable-length execution path (ISSUE 2).

Three layers of evidence that packing kills padding waste without
touching the math:

  * kernel parity — the segment-aware Pallas flash attention equals the
    block-diagonal masked reference in interpret mode across mask modes,
    uneven segment lengths and 1..8 segments (atol 1e-4, fp32);
  * packing correctness — flatten_group's labels/mask/positions never
    leak across segment boundaries, and each packed segment reproduces
    the same attention output as running that sequence alone;
  * executor invariants — packed vs per-sequence execution produces the
    SAME loss/gradients, with exe-miss count O(#buckets) (not
    O(#n_seqs)) and padding efficiency >= 0.85 on a heterogeneous
    RaggedBatch.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.group_pool import (GroupPool, geometric_bucket,
                                   make_bucket_fn, multiple_bucket,
                                   pow2_bucket)
from repro.core.packing import flatten_group, packing_efficiency
from repro.kernels.flash_attention import flash_attention_packed_flat
from repro.kernels.ops import flash_attention_packed
from repro.kernels.ref import flash_attention_packed_ref

KEY = jax.random.PRNGKey(0)

SEGMENT_SETS = [
    [64],                                # 1 segment
    [37, 27],                            # 2, uneven
    [5, 60, 3],                          # 3, very uneven
    [17, 1, 29, 13],                     # 4, incl. length-1
    [9, 9, 9, 9, 9, 9, 9, 9],            # 8 equal
    [31, 2, 19, 7, 11, 23, 3, 24],       # 8 uneven
]


def _packed_inputs(lens, BH=2, D=32, pad_to=None):
    total = sum(lens)
    S = pad_to or total
    seg = np.full(S, -1, np.int32)
    off = 0
    for i, L in enumerate(lens):
        seg[off:off + L] = i
        off += L
    q = jax.random.normal(KEY, (BH, S, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (BH, S, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (BH, S, D))
    return q, k, v, jnp.asarray(seg)


# ---------------------------------------------------------- kernel parity
@pytest.mark.parametrize("mode,window", [("causal", None), ("full", None),
                                         ("sliding", 8)])
@pytest.mark.parametrize("lens", SEGMENT_SETS,
                         ids=[f"{len(s)}seg" + ("-uneven" if len(set(s)) > 1
                                                else "")
                              for s in SEGMENT_SETS])
def test_packed_kernel_matches_blockdiag_ref(mode, window, lens):
    # tail padding: pad the packed buffer past the last segment
    q, k, v, seg = _packed_inputs(lens, pad_to=sum(lens) + 13)
    out = flash_attention_packed_flat(q, k, v, seg, mode=mode,
                                      window=window, block_q=32,
                                      block_k=32)
    ref = flash_attention_packed_ref(q, k, v, seg, mode=mode,
                                     window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_packed_kernel_padding_rows_are_zero():
    q, k, v, seg = _packed_inputs([20, 12], pad_to=64)
    out = flash_attention_packed_flat(q, k, v, seg, mode="causal",
                                      block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out[:, 32:]), 0.0, atol=0.0)


def test_packed_segments_equal_sequences_run_alone():
    """Each packed segment must reproduce the sequence run on its own —
    packing changes layout, never attention results."""
    from repro.kernels.ref import flash_attention_ref
    lens = [24, 40, 9]
    q, k, v, seg = _packed_inputs(lens, pad_to=96)
    out = flash_attention_packed_flat(q, k, v, seg, mode="causal",
                                      block_q=32, block_k=32)
    off = 0
    for L in lens:
        alone = flash_attention_ref(q[:, off:off + L], k[:, off:off + L],
                                    v[:, off:off + L], mode="causal")
        np.testing.assert_allclose(np.asarray(out[:, off:off + L]),
                                   np.asarray(alone), atol=1e-4,
                                   rtol=1e-4)
        off += L


def test_packed_ops_wrapper_gqa():
    """[B,S,H,D] wrapper with GQA expansion + per-row segment tables."""
    B, S, H, Hkv, D = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, D))
    seg = np.stack([
        np.repeat(np.arange(4), 16),          # row 0: 4x16 segments
        np.r_[np.zeros(50, int), -np.ones(14, int)],  # row 1: 1 + pad
    ]).astype(np.int32)
    out = flash_attention_packed(q, k, v, jnp.asarray(seg), mode="causal")
    ref = flash_attention_packed(q, k, v, jnp.asarray(seg), mode="causal",
                                 ref=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------- chunked core + grads
def test_packed_chunked_forward_and_grads():
    """The differentiable (custom-VJP) chunked path used by the
    executor: packed forward and gradients equal the block-diagonal
    reference."""
    from repro.models.attention import attn_chunked, attn_reference
    lens = [23, 41, 9]
    B, H, Hkv, D = 1, 4, 2, 16
    S = 96
    seg = np.full(S, -1, np.int32)
    off = 0
    for i, L in enumerate(lens):
        seg[off:off + L] = i
        off += L
    segj = jnp.asarray(seg)[None]
    q = jax.random.normal(KEY, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hkv, D))

    out = attn_chunked(q, k, v, mode="causal", chunk=32, segment_ids=segj)
    ref = attn_reference(q, k, v, mode="causal", segment_ids=segj)
    valid = off
    np.testing.assert_allclose(np.asarray(out[:, :valid]),
                               np.asarray(ref[:, :valid]),
                               atol=2e-5, rtol=2e-5)

    g = jax.grad(lambda q, k, v: (attn_chunked(
        q, k, v, mode="causal", chunk=32,
        segment_ids=segj)[:, :valid] ** 2).sum(), argnums=(0, 1, 2))(
        q, k, v)
    gr = jax.grad(lambda q, k, v: (attn_reference(
        q, k, v, mode="causal",
        segment_ids=segj)[:, :valid] ** 2).sum(), argnums=(0, 1, 2))(
        q, k, v)
    for a, b in zip(g, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-4)


# ------------------------------------------------------- flatten_group
def test_flatten_group_format():
    seqs = [np.arange(5, dtype=np.int32) + 1,
            np.arange(3, dtype=np.int32) + 100,
            np.array([7], dtype=np.int32)]
    batch, cu = flatten_group(seqs, bucket=16)
    assert list(cu) == [0, 5, 8, 9]
    t = batch["tokens"][0]
    np.testing.assert_array_equal(t[:5], [1, 2, 3, 4, 5])
    np.testing.assert_array_equal(t[5:8], [100, 101, 102])
    assert t[8] == 7 and (t[9:] == 0).all()
    # labels: next token WITHIN each segment; boundary + tail masked
    lab, m = batch["labels"][0], batch["mask"][0]
    np.testing.assert_array_equal(lab[:4], [2, 3, 4, 5])
    assert m[4] == 0.0          # last token of segment 0: no label
    np.testing.assert_array_equal(lab[5:7], [101, 102])
    assert m[7] == 0.0 and m[8] == 0.0      # len-1 segment: nothing
    assert m.sum() == (5 - 1) + (3 - 1) + 0
    # positions reset per segment
    pos = batch["positions"][0]
    np.testing.assert_array_equal(pos[:9], [0, 1, 2, 3, 4, 0, 1, 2, 0])
    # segment table with -1 tail
    np.testing.assert_array_equal(batch["segment_ids"][0][:9],
                                  [0, 0, 0, 0, 0, 1, 1, 1, 2])
    assert (batch["segment_ids"][0][9:] == -1).all()
    assert packing_efficiency(cu, 16) == pytest.approx(9 / 16)


def test_flatten_group_overflow_raises():
    with pytest.raises(ValueError):
        flatten_group([np.zeros(10, np.int32)], bucket=8)


# ------------------------------------------------------- bucket ladders
def test_bucket_ladders():
    assert pow2_bucket(100, 64) == 128
    assert pow2_bucket(129, 64) == 256
    # geometric 1.25x: monotone, >= n, 8-aligned, bounded waste (the
    # rungs don't coincide with pow2's, but overhead stays ~1.25x where
    # pow2's worst case is 2x)
    prev = 0
    for n in (65, 100, 200, 500, 1000, 5000):
        b = geometric_bucket(n, minimum=64)
        assert b >= n and b % 8 == 0 and b >= prev
        assert b <= n * 1.25 + 8
        prev = b
    assert multiple_bucket(100, 256) == 256
    assert multiple_bucket(257, 256) == 512
    assert multiple_bucket(512, 256) == 512
    assert make_bucket_fn("mult256")(300) == 512
    assert make_bucket_fn(lambda n: n)(123) == 123
    with pytest.raises(ValueError):
        make_bucket_fn("fib")


def test_group_pool_lru_eviction():
    pool = GroupPool(jax.devices() * 4, max_executables=2)
    _, miss = pool.executable_for("a", lambda: "A")
    assert miss
    pool.executable_for("b", lambda: "B")
    exe, miss = pool.executable_for("a", lambda: "A2")   # hit refreshes a
    assert exe == "A" and not miss
    pool.executable_for("c", lambda: "C")        # over cap: evicts b (LRU)
    assert pool.stats.exe_evictions == 1 and len(pool) == 2
    _, miss = pool.executable_for("b", lambda: "B2")     # b gone: re-miss
    assert miss                                          # (evicts a)
    exe, miss = pool.executable_for("c", lambda: "C2")   # c survived
    assert exe == "C" and not miss
    assert pool.stats.exe_misses == 4
    assert pool.stats.exe_hits == 2
    assert pool.stats.exe_evictions == 2


# ------------------------------------------------------ executor level
def _demo(cfg):
    from repro.core import CostModel, analytic_coeffs
    coeffs = dataclasses.replace(
        analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                        n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                        ffn=cfg.d_ff, vocab=cfg.vocab),
        m_ms=0.0, m_token=1.0)
    return CostModel(coeffs)


def test_executor_packed_kills_exe_explosion_and_padding():
    """The acceptance criteria of the issue, on ONE host device:

      * packed and per-sequence paths produce the same loss/grads;
      * packed exe-miss count is O(#buckets): one executable per
        distinct (degree, packed bucket), with n_seqs gone — at least
        2x fewer compilations than the per-sequence path;
      * padding efficiency >= 0.85 on a heterogeneous RaggedBatch
        (mult256 ladder), and strictly better than per-sequence pow2.
    """
    from repro.configs import get_config
    from repro.core import DHPScheduler
    from repro.core.executor import DHPExecutor
    from repro.data.pipeline import HeterogeneousLoader
    from repro.models.model import init_params

    cfg = get_config("internvl3-2b").reduced().with_(family="dense",
                                                     vlm=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    loader = HeterogeneousLoader("openvid", 24, cfg.vocab, seed=5,
                                 max_tokens=700, tokens_per_frame=16)
    data = next(iter(loader))
    plan = DHPScheduler(_demo(cfg), 1, mem_budget=1200.0).schedule(
        data.infos)
    n_groups = plan.n_groups
    assert n_groups >= 4      # heterogeneous enough to be interesting

    pool_p = GroupPool(jax.devices(), bucket_fn="mult256")
    pool_u = GroupPool(jax.devices(), bucket_fn="pow2")
    ex_p = DHPExecutor(cfg, pool=pool_p, packed=True)
    ex_u = DHPExecutor(cfg, pool=pool_u, packed=False)
    l_p, g_p = ex_p.run_plan(params, plan, data)
    l_u, g_u = ex_u.run_plan(params, plan, data)

    # same math
    assert abs(float(l_p) - float(l_u)) < 2e-5
    err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
              for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_u)))
    assert err < 1e-4, err

    # executable space: one exe per distinct (degree, packed bucket)
    packed_keys = set()
    for mb in plan.micro_batches:
        for g in mb.groups:
            total = sum(len(data.by_id(i)) for i in g.seq_ids)
            b = pool_p.bucket(total)
            b += (-b) % g.degree
            packed_keys.add((g.degree, b))
    assert pool_p.stats.exe_misses == len(packed_keys)
    assert pool_p.stats.exe_misses <= n_groups
    # n_seqs is gone: the per-sequence path compiles >= 2x more
    assert pool_u.stats.exe_misses >= 2 * pool_p.stats.exe_misses, (
        pool_u.stats, pool_p.stats)

    # padding: >= 0.85 packed (mult256), and better than per-seq pow2
    eff_p = ex_p.last_run_stats["padding_efficiency"]
    eff_u = ex_u.last_run_stats["padding_efficiency"]
    assert eff_p >= 0.85, ex_p.last_run_stats
    assert eff_p > eff_u, (eff_p, eff_u)
    # >= 30% reduction of padded-token overhead (overhead = padded-real)
    over_p = ex_p.last_run_stats["padded_tokens"] - \
        ex_p.last_run_stats["real_tokens"]
    over_u = ex_u.last_run_stats["padded_tokens"] - \
        ex_u.last_run_stats["real_tokens"]
    assert over_p <= 0.7 * over_u, (over_p, over_u)

    # warm pool: re-running compiles nothing, timing records say so
    timings = []
    ex_p.run_plan(params, plan, data, timings=timings)
    assert ex_p.last_run_stats["exe_misses"] == 0
    assert all(not t["compiled"] for t in timings)
    assert all(0 < t["padding_efficiency"] <= 1 for t in timings)
    assert {"real_tokens", "padded_tokens"} <= set(timings[0])


def test_executor_packed_rejects_stateful_families():
    from repro.configs import get_config
    from repro.core.executor import DHPExecutor
    cfg = get_config("mamba2-370m").reduced()
    with pytest.raises(ValueError):
        DHPExecutor(cfg, packed=True)
    ex = DHPExecutor(cfg)          # default: packed auto-disables
    assert not ex.packed


def test_ring_packed_segments(subproc):
    """Segment-aware ring CP: a packed buffer sharded over cp=3 must
    match the single-device block-diagonal reference — the segment
    table travels with each ppermute hop."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel.compat import shard_map
from repro.parallel.ring_attention import ring_attention
from repro.models.attention import attn_reference

devs = jax.devices()
mesh = Mesh(np.array(devs[:3]), ("cp",))
B,H,Hkv,Dh = 1, 4, 2, 16
lens = [25, 40, 14, 17]         # 96 tokens = 3 shards x 32
S = 96
seg = np.full(S, -1, np.int32); pos = np.zeros(S, np.int32); off = 0
for i, L in enumerate(lens):
    seg[off:off+L] = i; pos[off:off+L] = np.arange(L); off += L
key = jax.random.PRNGKey(0)
q = jax.random.normal(key,(B,S,H,Dh))
k = jax.random.normal(jax.random.fold_in(key,1),(B,S,Hkv,Dh))
v = jax.random.normal(jax.random.fold_in(key,2),(B,S,Hkv,Dh))
posj = jnp.asarray(pos)[None]
segj = jnp.asarray(seg)[None]
fm = shard_map(
    lambda q,k,v,p,s: ring_attention(q,k,v,p,axis_name="cp",q_seg=s),
    mesh=mesh, in_specs=(P(None,"cp"),)*5, out_specs=P(None,"cp"))
out = fm(q,k,v,posj,segj)
ref = attn_reference(q,k,v,mode="causal",segment_ids=segj)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=3e-5, rtol=3e-5)
# grads flow through the segment-aware ring too
g = jax.grad(lambda q,k,v: (fm(q,k,v,posj,segj)**2).sum(),
             argnums=(0,1,2))(q,k,v)
gr = jax.grad(lambda q,k,v: (attn_reference(
    q,k,v,mode="causal",segment_ids=segj)**2).sum(),
             argnums=(0,1,2))(q,k,v)
for a,b in zip(g,gr):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=5e-4, rtol=5e-4)
print("ring packed ok")
""", n_devices=3)


def test_executor_packed_multidevice_cp(subproc):
    """Full packed execution with CP degree > 1 on 8 host devices:
    packed-vs-per-sequence gradient equivalence must survive sharding
    the packed buffer over the cp axis."""
    subproc("""
import dataclasses, jax, numpy as np
from repro.configs import get_config
from repro.core import CostModel, DHPScheduler, analytic_coeffs
from repro.core.executor import DHPExecutor
from repro.data.pipeline import HeterogeneousLoader
from repro.models.model import init_params

cfg = get_config("internvl3-2b").reduced().with_(family="dense", vlm=None)
params = init_params(jax.random.PRNGKey(0), cfg)
loader = HeterogeneousLoader("openvid", 12, cfg.vocab, seed=1,
                             max_tokens=512, tokens_per_frame=16)
data = next(iter(loader))
coeffs = dataclasses.replace(
    analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                    n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                    ffn=cfg.d_ff, vocab=cfg.vocab), m_ms=0.0, m_token=1.0)
plan = DHPScheduler(CostModel(coeffs), 8, mem_budget=900.0).schedule(
    data.infos)
assert any(g.degree > 1 for mb in plan.micro_batches for g in mb.groups)
ex_p = DHPExecutor(cfg, packed=True)
ex_u = DHPExecutor(cfg, packed=False)
l_p, g_p = ex_p.run_plan(params, plan, data)
l_u, g_u = ex_u.run_plan(params, plan, data)
assert abs(float(l_p) - float(l_u)) < 2e-5, (float(l_p), float(l_u))
err = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
          for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_u)))
assert err < 1e-4, err
assert ex_p.last_run_stats["padding_efficiency"] >= \
    ex_u.last_run_stats["padding_efficiency"]
print("packed cp ok", err)
""", n_devices=8)
