"""The assigned architectures must match the assignment sheet exactly."""
import pytest

from repro.configs import ALL_ARCHS, ASSIGNED_ARCHS, INPUT_SHAPES, \
    get_config

EXPECTED = {
    # arch: (L, d_model, H, kv, d_ff, vocab, family)
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155, "moe"),
    "llama3-405b": (126, 16384, 128, 8, 53248, 128256, "dense"),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304, "moe"),
    "whisper-small": (12, 768, 12, 12, 3072, 51865, "audio"),
    "minitron-4b": (32, 3072, 24, 8, 9216, 256000, "dense"),
    "glm4-9b": (40, 4096, 32, 2, 13696, 151552, "dense"),
    "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000, "hybrid"),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, "dense"),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280, "ssm"),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072, "vlm"),
}


def test_ten_archs_assigned():
    assert len(ASSIGNED_ARCHS) == 10
    assert set(ASSIGNED_ARCHS) == set(EXPECTED)


@pytest.mark.parametrize("arch", list(EXPECTED))
def test_config_matches_assignment(arch):
    L, d, h, kv, ff, v, fam = EXPECTED[arch]
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == h
    assert cfg.kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab == v
    assert cfg.family == fam
    assert cfg.source, "every config must cite its source"


def test_family_extras():
    assert get_config("granite-moe-1b-a400m").moe.n_experts == 32
    assert get_config("granite-moe-1b-a400m").moe.top_k == 8
    assert get_config("olmoe-1b-7b").moe.n_experts == 64
    assert get_config("mamba2-370m").ssm.d_state == 128
    assert get_config("recurrentgemma-2b").hybrid.pattern == \
        ("rec", "rec", "attn")
    assert get_config("recurrentgemma-2b").hybrid.window == 2048
    assert get_config("whisper-small").encdec.n_audio_frames == 1500
    assert get_config("pixtral-12b").vlm.vision_dim == 1024
    assert get_config("chatglm3-6b").rope_2d


def test_input_shapes_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == \
        (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == \
        (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == \
        (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == \
        (524288, 1)


def test_reduced_variants_bounded():
    for arch in ALL_ARCHS:
        r = get_config(arch).reduced()
        assert r.n_layers <= 3
        assert r.d_model <= 512
        if r.moe:
            assert r.moe.n_experts <= 4


def test_sub_quadratic_flags():
    assert get_config("mamba2-370m").sub_quadratic()
    assert get_config("recurrentgemma-2b").sub_quadratic()
    assert not get_config("llama3-405b").sub_quadratic()
    assert get_config("llama3-405b").with_(
        sliding_window=8192).sub_quadratic()
