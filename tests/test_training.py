"""Training substrate: optimizer, schedules, checkpointing, data
pipeline, profiler, simulator."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (CostModel, Profiler, analytic_coeffs,
                        end_to_end_table, sample_batch, scaling_table)
from repro.core.cost_model import Hardware, SeqInfo
from repro.data.pipeline import (HeterogeneousLoader, padded_batch,
                                 synthetic_batch)
from repro.models.model import forward, init_params
from repro.training.checkpoint import restore, save
from repro.training.optimizer import (AdamW, clip_by_global_norm,
                                      cosine_schedule, global_norm)
from repro.training.train_step import TrainState, make_train_step

KEY = jax.random.PRNGKey(0)


def test_cross_entropy_onehot_equals_gather():
    """The vocab-sharding-safe one-hot formulation (§Perf P4) must equal
    the take_along_axis reference."""
    from repro.training.train_step import cross_entropy
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (2, 8, 64))
    labels = jax.random.randint(jax.random.fold_in(key, 1), (2, 8), 0, 64)
    mask = (jnp.arange(8)[None, :] < jnp.array([[5], [8]])).astype(
        jnp.float32)
    got = cross_entropy(logits, labels, mask)
    lz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    want = ((lz - gold) * mask).sum() / mask.sum()
    assert float(got) == pytest.approx(float(want), rel=1e-6)


# ---------------------------------------------------------------- optimizer
def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_states():
    opt = AdamW(lr=1e-3, state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    params2, _ = opt.update({"w": jnp.ones((4, 4), jnp.bfloat16)},
                            state, params)
    assert params2["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    assert float(norm) == pytest.approx(np.sqrt(90.0), rel=1e-5)


# ---------------------------------------------------------------- loss path
def test_loss_decreases_over_steps():
    cfg = get_config("internvl3-2b").reduced().with_(family="dense",
                                                     vlm=None)
    params = init_params(KEY, cfg)
    opt = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(cfg, opt))
    state = TrainState(params, opt.init(params))
    # overfit one tiny batch
    toks = jax.random.randint(KEY, (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params = init_params(KEY, cfg)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save(path, params)
        like = jax.tree.map(jnp.zeros_like, params)
        back = restore(path, like)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _tiny_engine():
    from repro.api import Engine
    from repro.training.optimizer import AdamW
    return Engine("internvl3-2b", strategy="dhp", reduced=True, seed=0,
                  optimizer=AdamW(lr=1e-3))


TRAIN_KW = dict(dataset="openvid", global_batch=4, max_tokens=64,
                lookahead=False)


def test_checkpoint_full_state_resume():
    """Interrupt-at-2 + resume-for-2 equals an uninterrupted 4-step
    run: params, optimizer moments, step counter AND the loader stream
    position are all restored (the PR-4 resume-correctness fix)."""
    eng = _tiny_engine()
    eng.train(steps=2, **TRAIN_KW)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        eng.save_checkpoint(path)

        resumed = _tiny_engine()
        resumed.load_checkpoint(path)
        assert resumed._step == 2
        # optimizer step counter came back too, not just params
        assert int(resumed.state.opt.step) == 2
        resumed.train(steps=2, **TRAIN_KW)
        assert resumed.loader.batch_index == 4   # continued the stream

        full = _tiny_engine()
        full.train(steps=4, **TRAIN_KW)

        for a, b in zip(jax.tree.leaves(full.state.params),
                        jax.tree.leaves(resumed.state.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-6)
        for a, b in zip(jax.tree.leaves(full.state.opt.m),
                        jax.tree.leaves(resumed.state.opt.m)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-6)
        full.close()
        resumed.close()
    eng.close()


def test_checkpoint_old_params_only_format_still_loads():
    eng = _tiny_engine()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "old.npz")
        save(path, eng.state.params)      # pre-format-2 layout
        other = _tiny_engine()
        other.load_checkpoint(path)
        for a, b in zip(jax.tree.leaves(eng.state.params),
                        jax.tree.leaves(other.state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------- data
def test_heterogeneous_loader_deterministic():
    l1 = list(next(iter(HeterogeneousLoader("openvid", 8, 100, seed=3))).tokens)
    l2 = list(next(iter(HeterogeneousLoader("openvid", 8, 100, seed=3))).tokens)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(a, b)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(2, 300), min_size=1, max_size=8),
       st.sampled_from([64, 128, 256]))
def test_padded_batch_properties(lens, bucket):
    seqs = [np.arange(n, dtype=np.int32) % 97 + 1 for n in lens]
    b = padded_batch(seqs, bucket)
    assert b["tokens"].shape == (len(lens), bucket)
    # mask counts = min(len, bucket) - 1 valid predictions per row
    want = sum(min(n, bucket) - 1 for n in lens)
    assert int(b["mask"].sum()) == want
    # labels are next tokens wherever mask is on
    m = b["mask"].astype(bool)
    rolled = np.roll(b["tokens"], -1, axis=1)
    np.testing.assert_array_equal(b["labels"][m], rolled[m])


def test_synthetic_batch_shapes_vlm_audio():
    from repro.configs.base import InputShape
    shape = InputShape("t", 64, 2, "train")
    for arch in ("pixtral-12b", "whisper-small"):
        cfg = get_config(arch).reduced()
        b = synthetic_batch(cfg, shape)
        assert b["tokens"].shape == (2, 64)
        if cfg.family == "vlm":
            assert b["patch_embeds"].shape[2] == cfg.vlm.vision_dim
        if cfg.family == "audio":
            assert b["frames"].shape[1] == cfg.encdec.n_audio_frames


# ---------------------------------------------------------------- profiler
def test_profiler_fit_recovers_coefficients():
    """Table-3 machinery: fit on synthetic samples generated from known
    coefficients, verify low error."""
    true = CostModel(
        analytic_coeffs(hidden=2048, n_layers=24, n_heads=16, kv_heads=8,
                        ffn=8192, vocab=50000))
    prof = Profiler(hw=true.hw)
    for L in (512, 1024, 2048, 4096, 8192):
        for d in (1, 2, 3, 4, 6, 8):
            for eta in (0.0, 0.5, 1.0):
                t = true.group_time([SeqInfo(length=L, eta=eta)], d)
                prof.add_sample(L, d, eta, t)
    err = prof.error()
    assert err < 8.0, f"estimator error {err}% (paper: <8%)"


def test_profiler_fit_on_measured_cpu_steps():
    """Fit on real timed CPU forward passes of the reduced model."""
    import time
    cfg = get_config("internvl3-2b").reduced().with_(family="dense",
                                                     vlm=None)
    params = init_params(KEY, cfg)

    @jax.jit
    def fwd(params, toks):
        logits, _ = forward(params, cfg, {"tokens": toks})
        return logits.sum()

    def measure(L, d, eta):
        toks = jnp.zeros((1, L), jnp.int32)
        fwd(params, toks).block_until_ready()     # compile
        t0 = time.perf_counter()
        for _ in range(3):
            fwd(params, toks).block_until_ready()
        return (time.perf_counter() - t0) / 3 / d  # ideal-CP proxy

    prof = Profiler()
    prof.collect(measure, lengths=[128, 256, 512], degrees=[1, 2])
    prof.fit()
    err = prof.error()
    assert err < 35.0, f"measured-fit error {err}%"


# ---------------------------------------------------------------- simulator
def test_simulated_speedup_reproduces_paper_range():
    """Fig. 4/6: DHP beats the best static baseline; diverse datasets
    gain more than uniform ones."""
    cm = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                                   kv_heads=4, ffn=18944, vocab=152000))
    rows = end_to_end_table(cm, n_ranks=64, mem_budget=8e9, gbs=256,
                            iters=2, max_tokens=262144)
    by = {r["dataset"]: r for r in rows}
    for ds in ("msrvtt", "internvid", "openvid"):
        assert by[ds]["speedup_vs_best_static"] > 1.0, by[ds]
    assert (by["openvid"]["speedup_vs_best_static"]
            > by["msrvtt"]["speedup_vs_best_static"])


def test_scaling_table_runs():
    cm = CostModel(analytic_coeffs(hidden=2048, n_layers=24, n_heads=16,
                                   kv_heads=8, ffn=8192, vocab=50000))
    rows = scaling_table(cm, rank_counts=(8, 16), mem_budget=8e9, gbs=64,
                         iters=1, max_tokens=131072)
    assert len(rows) == 2
    for r in rows:
        assert r["dhp_vs_deepspeed"] > 0.95
