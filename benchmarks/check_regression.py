"""CI gate: fail when median scheduling latency regresses vs baseline.

Compares the `*/schedule_ms` rows of a freshly generated
`benchmarks/run.py --json` file against the newest committed
`BENCH_*.json` baseline (the artifact a previous PR checked in). The
gate trips when the median regresses by more than `--threshold` (default
1.2 = +20%); when no baseline exists — or the baseline is the file being
checked — it skips cleanly so the first PR can bootstrap the trajectory.

Two further gates (PR 7, millisecond-class planning):

  * `*/allocate_us` — the Stage-2 allocator's per-batch time. Gated by
    ratio vs the baseline's median when the baseline carries the rows;
    when it does not (baselines predating PR 7), the median must stay
    under `--allocate-budget` x `calibration/host_speed` — host_speed
    times a FIXED legacy pure-Python DP solve, so "budget 1.5" is a
    host-independent statement of "<= ~3 ms on the reference runner"
    (where host_speed ~ 2 ms and the legacy allocator needed ~17 ms).
  * `lookahead/speedup` (sync wall / pipelined wall) — the pipelined
    planner must not lose to the synchronous one:
    speedup >= 1 / `--lookahead-tolerance`. The default tolerance
    absorbs the ~5% run-to-run noise of host-device step timing.

One gate from PR 9 (observability):

  * `trace/overhead` (traced / untraced median per-plan wall, see
    bench_end_to_end.run_trace_overhead) — a live Tracer must cost the
    planner at most `--trace-tolerance` (default 1.05 = <=5%).

  PYTHONPATH=src python -m benchmarks.check_regression --new BENCH_pr3.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import statistics
import sys


def load_rows(path: str) -> list:
    with open(path) as f:
        return json.load(f).get("rows", [])


def schedule_ms_values(rows: list) -> list:
    return [r["value"] for r in rows
            if r["name"].endswith("/schedule_ms")]


def suffix_values(rows: list, suffix: str) -> list:
    return [r["value"] for r in rows if r["name"].endswith(suffix)]


def named_value(rows: list, name: str):
    for r in rows:
        if r["name"] == name:
            return r["value"]
    return None


def calibration(rows: list):
    """The fixed-workload machine-speed row run.py always emits; when
    BOTH files carry it, medians are normalized by it so the gate
    compares scheduling efficiency, not runner hardware."""
    for r in rows:
        if r["name"] == "calibration/host_speed" and r["value"] > 0:
            return r["value"]
    return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True,
                    help="freshly generated run.py --json output")
    ap.add_argument("--baseline-glob", default="BENCH_*.json",
                    help="committed baseline files to compare against")
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="max allowed new/old median ratio")
    ap.add_argument("--allocate-budget", type=float, default=1.5,
                    help="absolute Stage-2 budget (x host_speed) when "
                         "the baseline has no */allocate_us rows")
    ap.add_argument("--lookahead-tolerance", type=float, default=1.05,
                    help="pipelined step wall may exceed sync by at "
                         "most this factor")
    ap.add_argument("--trace-tolerance", type=float, default=1.05,
                    help="max traced/untraced planning-time ratio "
                         "(the tracing-overhead budget)")
    args = ap.parse_args()

    new_abs = os.path.abspath(args.new)

    def pr_order(path):
        # numeric-aware: BENCH_pr10.json sorts after BENCH_pr9.json
        nums = [int(s) for s in re.findall(r"\d+",
                                           os.path.basename(path))]
        return (nums, path)

    baselines = sorted((p for p in glob.glob(args.baseline_glob)
                        if os.path.abspath(p) != new_abs),
                       key=pr_order)
    if not baselines:
        print(f"no baseline matching {args.baseline_glob!r} "
              f"(other than {args.new}) — skipping regression gate")
        return 0
    baseline = baselines[-1]          # newest committed trajectory point

    new_rows, old_rows = load_rows(args.new), load_rows(baseline)
    new_vals = schedule_ms_values(new_rows)
    old_vals = schedule_ms_values(old_rows)
    if not new_vals or not old_vals:
        print(f"no */schedule_ms rows in "
              f"{args.new if not new_vals else baseline} — skipping")
        return 0

    med_new = statistics.median(new_vals)
    med_old = statistics.median(old_vals)
    cal_new, cal_old = calibration(new_rows), calibration(old_rows)
    if cal_new and cal_old:
        med_new, med_old = med_new / cal_new, med_old / cal_old
        unit = "x host-speed-normalized"
    else:
        unit = "us (raw — no calibration row in one file)"
    ratio = med_new / med_old if med_old > 0 else float("inf")
    print(f"median schedule_ms: {med_old:.4g} ({baseline}) -> "
          f"{med_new:.4g} ({args.new}) [{unit}]; ratio {ratio:.3f} "
          f"(threshold {args.threshold})")
    failed = False
    if ratio > args.threshold:
        print(f"FAIL: scheduling latency regressed "
              f">{(args.threshold - 1) * 100:.0f}%")
        failed = True

    # ---- Stage-2 allocator gate (*/allocate_us) ----------------------
    alloc_new = suffix_values(new_rows, "/allocate_us")
    if alloc_new:
        med_a_new = statistics.median(alloc_new)
        alloc_old = suffix_values(old_rows, "/allocate_us")
        if alloc_old:
            med_a_old = statistics.median(alloc_old)
            a_new, a_old = med_a_new, med_a_old
            if cal_new and cal_old:
                a_new, a_old = a_new / cal_new, a_old / cal_old
            a_ratio = a_new / a_old if a_old > 0 else float("inf")
            print(f"median allocate_us: {med_a_old:.4g} ({baseline}) "
                  f"-> {med_a_new:.4g} ({args.new}); normalized ratio "
                  f"{a_ratio:.3f} (threshold {args.threshold})")
            if a_ratio > args.threshold:
                print("FAIL: Stage-2 allocate time regressed")
                failed = True
        elif cal_new:
            # first PR carrying the rows: absolute budget in units of
            # the fixed legacy-DP calibration solve
            norm = med_a_new / cal_new
            print(f"median allocate_us: {med_a_new:.4g} = {norm:.3f} x "
                  f"host_speed (budget {args.allocate_budget}; no "
                  f"allocate_us rows in {baseline})")
            if norm > args.allocate_budget:
                print("FAIL: Stage-2 allocate time over absolute budget")
                failed = True

    # ---- lookahead gate (pipelined must not lose to sync) ------------
    speedup = named_value(new_rows, "lookahead/speedup")
    if speedup is not None:
        floor = 1.0 / args.lookahead_tolerance
        print(f"lookahead/speedup: {speedup:.3f} (floor {floor:.3f})")
        if speedup < floor:
            print("FAIL: pipelined lookahead lost to synchronous "
                  "planning beyond tolerance")
            failed = True

    # ---- tracing-overhead gate (trace/overhead) ----------------------
    trace_overhead = named_value(new_rows, "trace/overhead")
    if trace_overhead is not None:
        print(f"trace/overhead: {trace_overhead:.3f} "
              f"(budget {args.trace_tolerance})")
        if trace_overhead > args.trace_tolerance:
            print("FAIL: tracing overhead over budget")
            failed = True

    if failed:
        return 1
    print("ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
