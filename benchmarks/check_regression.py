"""CI gate: fail when median scheduling latency regresses vs baseline.

Compares the `*/schedule_ms` rows of a freshly generated
`benchmarks/run.py --json` file against the newest committed
`BENCH_*.json` baseline (the artifact a previous PR checked in). The
gate trips when the median regresses by more than `--threshold` (default
1.2 = +20%); when no baseline exists — or the baseline is the file being
checked — it skips cleanly so the first PR can bootstrap the trajectory.

  PYTHONPATH=src python -m benchmarks.check_regression --new BENCH_pr3.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import statistics
import sys


def load_rows(path: str) -> list:
    with open(path) as f:
        return json.load(f).get("rows", [])


def schedule_ms_values(rows: list) -> list:
    return [r["value"] for r in rows
            if r["name"].endswith("/schedule_ms")]


def calibration(rows: list):
    """The fixed-workload machine-speed row run.py always emits; when
    BOTH files carry it, medians are normalized by it so the gate
    compares scheduling efficiency, not runner hardware."""
    for r in rows:
        if r["name"] == "calibration/host_speed" and r["value"] > 0:
            return r["value"]
    return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", required=True,
                    help="freshly generated run.py --json output")
    ap.add_argument("--baseline-glob", default="BENCH_*.json",
                    help="committed baseline files to compare against")
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="max allowed new/old median ratio")
    args = ap.parse_args()

    new_abs = os.path.abspath(args.new)

    def pr_order(path):
        # numeric-aware: BENCH_pr10.json sorts after BENCH_pr9.json
        nums = [int(s) for s in re.findall(r"\d+",
                                           os.path.basename(path))]
        return (nums, path)

    baselines = sorted((p for p in glob.glob(args.baseline_glob)
                        if os.path.abspath(p) != new_abs),
                       key=pr_order)
    if not baselines:
        print(f"no baseline matching {args.baseline_glob!r} "
              f"(other than {args.new}) — skipping regression gate")
        return 0
    baseline = baselines[-1]          # newest committed trajectory point

    new_rows, old_rows = load_rows(args.new), load_rows(baseline)
    new_vals = schedule_ms_values(new_rows)
    old_vals = schedule_ms_values(old_rows)
    if not new_vals or not old_vals:
        print(f"no */schedule_ms rows in "
              f"{args.new if not new_vals else baseline} — skipping")
        return 0

    med_new = statistics.median(new_vals)
    med_old = statistics.median(old_vals)
    cal_new, cal_old = calibration(new_rows), calibration(old_rows)
    if cal_new and cal_old:
        med_new, med_old = med_new / cal_new, med_old / cal_old
        unit = "x host-speed-normalized"
    else:
        unit = "us (raw — no calibration row in one file)"
    ratio = med_new / med_old if med_old > 0 else float("inf")
    print(f"median schedule_ms: {med_old:.4g} ({baseline}) -> "
          f"{med_new:.4g} ({args.new}) [{unit}]; ratio {ratio:.3f} "
          f"(threshold {args.threshold})")
    if ratio > args.threshold:
        print(f"FAIL: scheduling latency regressed "
              f">{(args.threshold - 1) * 100:.0f}%")
        return 1
    print("ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
