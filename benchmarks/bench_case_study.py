"""Table 4 — case study: heterogeneous CP-group decompositions chosen by
DHP for OpenVid-like (case 1) vs MSRVTT-like (case 2) batches, vs the
static single-degree groups of Megatron/DeepSpeed."""
from __future__ import annotations

import numpy as np

from repro.core import (CostModel, DHPScheduler, analytic_coeffs,
                        sample_batch, static_plan)


def run(report):
    cm = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                                   kv_heads=4, ffn=18944, vocab=152000))
    budget = 3e9   # calibrated so d_min spans 1..8 like the paper cases
    rng = np.random.default_rng(7)
    for case, ds in (("case1", "openvid"), ("case2", "msrvtt")):
        seqs = sample_batch(ds, 64, rng, max_tokens=262144)
        # paper-faithful scheduler: shows the heterogeneous degree mix
        sched = DHPScheduler(cm, 32, budget, balance_packing=False,
                             serial_fallback=False)
        plan = sched.schedule(seqs)
        static = static_plan(seqs, cm, 32, budget)
        sdeg = static.micro_batches[0].groups[0].degree
        speedup = static.total_time_est / plan.total_time_est
        hist = "+".join(f"<{d}>x{c}" for d, c in
                        plan.degree_histogram.items())
        report(f"table4/{case}", plan.schedule_ms * 1e3,
               f"dhp_groups={hist} static=<{sdeg}> "
               f"speedup={speedup:.2f}x")
