"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1/2  — solver & scheduling latency (paper Tables 1-2)
  fig4      — end-to-end iteration time + speedup   (Figs. 4 & 6)
  fig5      — scaling: throughput vs rank count      (Fig. 5)
  table3    — cost-estimator error                   (Table 3)
  table4    — case-study CP-group decompositions     (Table 4)
  kernels   — flash-attention / rglru micro-bench

``--smoke`` runs the fast per-strategy end-to-end comparison only
(seconds, not minutes) — the CI perf canary that surfaces scheduling
regressions in PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: per-strategy end-to-end table")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []

    def report(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    if args.smoke:
        from . import bench_end_to_end
        mods = [("end_to_end[smoke]",
                 lambda r: bench_end_to_end.run_smoke(r))]
    else:
        from . import (bench_ablation, bench_case_study,
                       bench_end_to_end, bench_estimator, bench_kernels,
                       bench_scaling, bench_solver)
        mods = [("solver", bench_solver.run),
                ("end_to_end", bench_end_to_end.run),
                ("scaling", bench_scaling.run),
                ("estimator", bench_estimator.run),
                ("case_study", bench_case_study.run),
                ("ablation", bench_ablation.run),
                ("kernels", bench_kernels.run)]

    for name, runner in mods:
        try:
            runner(report)
        except Exception:   # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
