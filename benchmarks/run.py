"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1/2  — solver & scheduling latency (paper Tables 1-2)
  fig4      — end-to-end iteration time + speedup   (Figs. 4 & 6)
  fig5      — scaling: throughput vs rank count      (Fig. 5)
  table3    — cost-estimator error                   (Table 3)
  table4    — case-study CP-group decompositions     (Table 4)
  kernels   — flash-attention / rglru micro-bench
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (bench_ablation, bench_case_study, bench_end_to_end,
                   bench_estimator, bench_kernels, bench_scaling,
                   bench_solver)
    mods = [("solver", bench_solver), ("end_to_end", bench_end_to_end),
            ("scaling", bench_scaling), ("estimator", bench_estimator),
            ("case_study", bench_case_study), ("ablation", bench_ablation),
            ("kernels", bench_kernels)]
    print("name,us_per_call,derived")
    failed = []

    def report(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        sys.stdout.flush()

    for name, mod in mods:
        try:
            mod.run(report)
        except Exception:   # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
