"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1/2  — solver & scheduling latency (paper Tables 1-2)
  fig4      — end-to-end iteration time + speedup   (Figs. 4 & 6)
  fig5      — scaling: throughput vs rank count      (Fig. 5)
  table3    — cost-estimator error                   (Table 3)
  table4    — case-study CP-group decompositions     (Table 4)
  kernels   — flash-attention / rglru micro-bench

``--smoke`` runs the fast per-strategy end-to-end comparison only
(seconds, not minutes) — the CI perf canary that surfaces scheduling
regressions in PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: per-strategy end-to-end table "
                         "+ packed-execution metrics")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write every row to PATH as JSON — the CI "
                         "artifact that tracks padding_efficiency / "
                         "exe_misses across PRs")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "value": us, "derived": derived})
        sys.stdout.flush()

    if args.smoke:
        from . import bench_end_to_end, bench_kernels
        mods = [("end_to_end[smoke]",
                 lambda r: bench_end_to_end.run_smoke(r)),
                ("kernels[smoke]",
                 lambda r: bench_kernels.run_smoke(r))]
    else:
        from . import (bench_ablation, bench_case_study,
                       bench_end_to_end, bench_estimator, bench_kernels,
                       bench_scaling, bench_solver)
        mods = [("solver", bench_solver.run),
                ("end_to_end", bench_end_to_end.run),
                ("scaling", bench_scaling.run),
                ("estimator", bench_estimator.run),
                ("case_study", bench_case_study.run),
                ("ablation", bench_ablation.run),
                ("kernels", bench_kernels.run)]

    for name, runner in mods:
        try:
            runner(report)
        except Exception:   # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "failed": failed}, f, indent=1)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
