"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1/2  — solver & scheduling latency (paper Tables 1-2)
  fig4      — end-to-end iteration time + speedup   (Figs. 4 & 6)
  fig5      — scaling: throughput vs rank count      (Fig. 5)
  table3    — cost-estimator error                   (Table 3)
  table4    — case-study CP-group decompositions     (Table 4)
  kernels   — flash-attention / rglru micro-bench

``--smoke`` runs the fast per-strategy end-to-end comparison only
(seconds, not minutes) — the CI perf canary that surfaces scheduling
regressions in PRs.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def _calibration_row(report) -> None:
    """Machine-speed reference: a FIXED pure-Python 2D-DP solve — the
    same kind of host work `schedule_ms` measures. check_regression
    normalizes schedule-latency medians by this row, so the CI gate
    compares scheduling efficiency across PRs rather than runner
    hardware.

    Measured as the MIN over 7 repeats: the minimum of a fixed
    workload estimates machine speed free of contention spikes (a
    single cold sample was observed to swing ~2x between runs, which
    swung the gate's normalized medians with it).

    Times `allocate_reference` — the legacy pure-Python DP, kept
    verbatim — NOT the vectorized `allocate`: the normalizer must mean
    the same thing in every BENCH_*.json ever committed, and swapping
    the solver under it would silently rescale all older baselines."""
    import time

    from repro.core import allocate_reference as allocate
    from repro.core.cost_model import SeqInfo
    from repro.core.packing import AtomicGroup

    groups = [
        AtomicGroup(seqs=[SeqInfo(length=256 * (1 + i % 7), seq_id=i)],
                    d_min=1, capacity=1e9, used=0.0)
        for i in range(24)]

    def tf(seqs, d):
        return sum(s.length for s in seqs) / d + 0.1 * d

    best = float("inf")
    for _ in range(7):
        t0 = time.perf_counter()
        for _ in range(3):
            allocate(groups, 32, tf)
        best = min(best, time.perf_counter() - t0)
    report("calibration/host_speed", best * 1e6,
           "fixed 2D-DP solve (min of 7); schedule_ms normalizer for "
           "check_regression")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: per-strategy end-to-end table "
                         "+ packed-execution metrics")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write every row to PATH as JSON — the CI "
                         "artifact that tracks padding_efficiency / "
                         "exe_misses across PRs")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="save the traced smoke-train's Chrome "
                         "trace-event JSON (plus PATH.report.json run "
                         "report) — the CI observability artifact")
    args = ap.parse_args()

    if args.trace:
        from . import bench_end_to_end
        bench_end_to_end.TRACE_OUT = args.trace

    print("name,us_per_call,derived")
    failed = []
    rows = []

    def report(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.1f},{derived}")
        rows.append({"name": name, "value": us, "derived": derived})
        sys.stdout.flush()

    _calibration_row(report)

    if args.smoke:
        from . import (bench_end_to_end, bench_kernels, bench_serving,
                       bench_solver)
        mods = [("end_to_end[smoke]",
                 lambda r: bench_end_to_end.run_smoke(r)),
                ("solver[smoke]",
                 lambda r: bench_solver.run_smoke(r)),
                ("serving[smoke]",
                 lambda r: bench_serving.run_smoke(r)),
                ("kernels[smoke]",
                 lambda r: bench_kernels.run_smoke(r))]
    else:
        from . import (bench_ablation, bench_case_study,
                       bench_end_to_end, bench_estimator, bench_kernels,
                       bench_scaling, bench_serving, bench_solver)
        mods = [("solver", bench_solver.run),
                ("end_to_end", bench_end_to_end.run),
                ("serving", bench_serving.run),
                ("scaling", bench_scaling.run),
                ("estimator", bench_estimator.run),
                ("case_study", bench_case_study.run),
                ("ablation", bench_ablation.run),
                ("kernels", bench_kernels.run)]

    for name, runner in mods:
        try:
            runner(report)
        except Exception:   # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump({"rows": rows, "failed": failed}, f, indent=1)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
