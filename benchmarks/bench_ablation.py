"""Ablation of the beyond-paper scheduler refinements (§Perf-S):

  faithful        — the paper's exact BFD + 2D-DP
  +balance        — balance-aware Stage-1 packing only
  +serial         — serial small-group fallback only
  optimized       — both (the production default)

All four run on the same global batches under the same cost model, so
the rows isolate each refinement's contribution to the end-to-end
iteration-time estimate.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CostModel, DHPScheduler, analytic_coeffs,
                        sample_batch, static_plan)

VARIANTS = {
    "faithful": dict(balance_packing=False, serial_fallback=False),
    "+balance": dict(balance_packing=True, serial_fallback=False),
    "+serial": dict(balance_packing=False, serial_fallback=True),
    "optimized": dict(balance_packing=True, serial_fallback=True),
}


def run(report):
    cm = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                                   kv_heads=4, ffn=18944, vocab=152000))
    n_ranks, budget, iters = 64, 3e9, 4
    rng = np.random.default_rng(11)
    for ds in ("msrvtt", "openvid"):
        batches = [sample_batch(ds, 256, rng, max_tokens=262144)
                   for _ in range(iters)]
        static_t = sum(
            static_plan(seqs, cm, n_ranks, budget).total_time_est
            for seqs in batches)
        for name, kw in VARIANTS.items():
            tot, ms = 0.0, 0.0
            for seqs in batches:
                plan = DHPScheduler(cm, n_ranks, budget, **kw).schedule(
                    seqs)
                tot += plan.total_time_est
                ms += plan.schedule_ms
            report(f"ablation/{ds}/{name}", ms / iters * 1e3,
                   f"iter={tot / iters:.2f}s "
                   f"speedup_vs_static={static_t / tot:.2f}x")
