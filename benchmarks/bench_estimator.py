"""Table 3 — cost-estimator error (%). The Profiler fits Eq. 8-10
coefficients on a profiling grid and is scored on held-out lengths.
Paper: error < 8% across 2B/4B/8B."""
from __future__ import annotations

from repro.core import CostModel, Profiler, analytic_coeffs
from repro.core.cost_model import SeqInfo

MODELS = {
    "2b": dict(hidden=1536, n_layers=28, n_heads=12, kv_heads=2,
               ffn=8960, vocab=151674),
    "4b": dict(hidden=2048, n_layers=36, n_heads=16, kv_heads=8,
               ffn=11008, vocab=151674),
    "8b": dict(hidden=4096, n_layers=36, n_heads=32, kv_heads=8,
               ffn=12288, vocab=151674),
}


def run(report):
    import numpy as np
    rng = np.random.default_rng(0)
    for name, kw in MODELS.items():
        truth = CostModel(analytic_coeffs(**kw))
        prof = Profiler(hw=truth.hw)
        # profiling grid (train-time profile function)
        for L in (512, 1024, 2048, 4096, 8192, 16384):
            for d in (1, 2, 3, 4, 6, 8):
                t = truth.group_time([SeqInfo(length=L, eta=0.5)], d)
                # +-3% measurement noise, like a real NPU timer
                prof.add_sample(L, d, 0.5, t * (1 + rng.normal(0, 0.03)))
        prof.fit()
        # held-out: off-grid lengths and degrees
        holdout = []
        from repro.core.profiler import Sample
        for L in (768, 1536, 3072, 6144, 12288):
            for d in (2, 3, 5, 7):
                t = truth.group_time([SeqInfo(length=L, eta=0.5)], d)
                holdout.append(Sample(L, d, 0.5, t))
        err = prof.error(holdout)
        report(f"table3/{name}", err * 1e3,
               f"estimator_error={err:.2f}% (paper: <8%)")
