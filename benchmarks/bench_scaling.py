"""Figure 5 — token throughput vs NPU count (8..64): DHP holds or grows
its advantage as the cluster scales (paper: 1.02x -> 1.16x vs DeepSpeed).
"""
from __future__ import annotations

from repro.core import CostModel, analytic_coeffs, scaling_table


def run(report):
    cm = CostModel(analytic_coeffs(hidden=4096, n_layers=36, n_heads=32,
                                   kv_heads=8, ffn=12288, vocab=151674))
    rows = scaling_table(cm, rank_counts=(8, 16, 32, 64),
                         mem_budget=8e9, gbs=512, iters=2,
                         max_tokens=262144)
    for r in rows:
        report(f"fig5/ranks{r['ranks']}",
               1e6 / max(r["dhp_tokens_per_s_per_rank"], 1e-9),
               f"dhp={r['dhp_tokens_per_s_per_rank']:.0f}tok/s/rank "
               f"vs_deepspeed={r['dhp_vs_deepspeed']:.2f}x")
