"""Kernel micro-bench: Pallas flash attention (interpret mode) and the
pure-JAX flash path vs the naive reference — us/call on CPU.
(Wall-times are CPU-interpret numbers; the TPU story is in §Roofline.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _packed_lens():
    # a heterogeneous atomic group: 6 sequences, 488 real tokens
    return [180, 37, 121, 64, 9, 77]


def run_packed(report):
    """Packed-varlen flash attention vs the per-sequence padded
    equivalent — the kernel-level view of the executor's packed path.
    Reports padding_efficiency so the benchmark JSON tracks it."""
    import numpy as np
    from repro.kernels.ops import flash_attention, flash_attention_packed

    key = jax.random.PRNGKey(0)
    lens = _packed_lens()
    bucket = 512                      # mult256 bucket of 488 real tokens
    real = sum(lens)
    seg = np.full(bucket, -1, np.int32)
    off = 0
    for i, L in enumerate(lens):
        seg[off:off + L] = i
        off += L
    B, H, Hkv, D = 1, 4, 2, 64
    q = jax.random.normal(key, (B, bucket, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1),
                          (B, bucket, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2),
                          (B, bucket, Hkv, D))
    segj = jnp.asarray(seg)[None]

    t_packed = _time(lambda q, k, v: flash_attention_packed(
        q, k, v, segj, mode="causal"), q, k, v)
    report("kernels/attn_pallas_packed_512", t_packed,
           f"6 segments in one buffer, "
           f"padding_efficiency={real / bucket:.3f}")

    # per-sequence pow2-padded alternative: one call per sequence shape
    pow2 = [max(64, 1 << (L - 1).bit_length()) for L in lens]
    padded = sum(pow2)

    def per_seq(q, k, v):
        outs = []
        o = 0
        for L, b in zip(lens, pow2):
            qs = jnp.pad(q[:, o:o + L], ((0, 0), (0, b - L), (0, 0),
                                         (0, 0)))
            ks = jnp.pad(k[:, o:o + L], ((0, 0), (0, b - L), (0, 0),
                                         (0, 0)))
            vs = jnp.pad(v[:, o:o + L], ((0, 0), (0, b - L), (0, 0),
                                         (0, 0)))
            outs.append(flash_attention(qs, ks, vs, mode="causal"))
            o += L
        # one array depending on EVERY call, so block_until_ready in
        # _time waits for all 6 dispatches, not just the last
        return jnp.stack([x.sum() for x in outs])

    t_seq = _time(per_seq, q, k, v)
    report("kernels/attn_pallas_perseq_512", t_seq,
           f"same tokens, {len(lens)} pow2-padded calls, "
           f"padding_efficiency={real / padded:.3f}")
    report("kernels/packed_padding_efficiency", real / bucket * 100,
           f"vs per-seq {real / padded:.3f} "
           f"(value = percent, overhead x{(padded - real) / max(bucket - real, 1):.1f} less)")


def run_smoke(report):
    """CI subset: the packed-vs-padded kernel comparison only."""
    run_packed(report)


def run(report):
    from repro.kernels.ops import flash_attention
    from repro.models.attention import attn_chunked, attn_reference
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))

    t_ref = _time(jax.jit(lambda q, k, v: attn_reference(
        q, k, v, mode="causal")), q, k, v)
    report("kernels/attn_reference_512", t_ref, "naive full-matrix")
    t_chunk = _time(jax.jit(lambda q, k, v: attn_chunked(
        q, k, v, mode="causal", chunk=128)), q, k, v)
    report("kernels/attn_chunked_512", t_chunk,
           f"flash-jnp {t_ref / t_chunk:.2f}x vs ref")
    t_pal = _time(lambda q, k, v: flash_attention(
        q, k, v, mode="causal", block_q=128, block_k=128), q, k, v)
    report("kernels/attn_pallas_interp_512", t_pal,
           "interpret-mode (correctness harness, not TPU perf)")

    from repro.kernels.rglru_scan import rglru_scan_pallas
    from repro.kernels.ref import rglru_scan_ref
    a = jax.random.uniform(key, (2, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 3), (2, 512, 256))
    t_r = _time(jax.jit(rglru_scan_ref), a, b)
    report("kernels/rglru_ref_512", t_r, "sequential scan")
    t_p = _time(lambda a, b: rglru_scan_pallas(a, b, chunk=128), a, b)
    report("kernels/rglru_pallas_interp_512", t_p, "interpret mode")

    from repro.models.ssm import init_ssm, ssm_forward
    p_ssm = init_ssm(jax.random.fold_in(key, 4), 64, d_state=32,
                     head_dim=16, expand=2, conv_width=4,
                     dtype=jnp.float32)
    xs = jax.random.normal(jax.random.fold_in(key, 5), (2, 512, 64))
    fwd = lambda impl: jax.jit(lambda x: ssm_forward(    # noqa: E731
        p_ssm, x, d_state=32, head_dim=16, expand=2, chunk=64,
        impl=impl))
    t_j = _time(fwd("jnp"), xs)
    report("kernels/ssd_jnp_512", t_j, "chunked dual form, per-head map")
    t_sp = _time(fwd("pallas"), xs)
    report("kernels/ssd_pallas_interp_512", t_sp, "interpret mode")

    run_packed(report)
