"""Kernel micro-bench: Pallas flash attention (interpret mode) and the
pure-JAX flash path vs the naive reference — us/call on CPU.
(Wall-times are CPU-interpret numbers; the TPU story is in §Roofline.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(f, *args, iters=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def run(report):
    from repro.kernels.ops import flash_attention
    from repro.models.attention import attn_chunked, attn_reference
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))

    t_ref = _time(jax.jit(lambda q, k, v: attn_reference(
        q, k, v, mode="causal")), q, k, v)
    report("kernels/attn_reference_512", t_ref, "naive full-matrix")
    t_chunk = _time(jax.jit(lambda q, k, v: attn_chunked(
        q, k, v, mode="causal", chunk=128)), q, k, v)
    report("kernels/attn_chunked_512", t_chunk,
           f"flash-jnp {t_ref / t_chunk:.2f}x vs ref")
    t_pal = _time(lambda q, k, v: flash_attention(
        q, k, v, mode="causal", block_q=128, block_k=128), q, k, v)
    report("kernels/attn_pallas_interp_512", t_pal,
           "interpret-mode (correctness harness, not TPU perf)")

    from repro.kernels.rglru_scan import rglru_scan_pallas
    from repro.kernels.ref import rglru_scan_ref
    a = jax.random.uniform(key, (2, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 3), (2, 512, 256))
    t_r = _time(jax.jit(rglru_scan_ref), a, b)
    report("kernels/rglru_ref_512", t_r, "sequential scan")
    t_p = _time(lambda a, b: rglru_scan_pallas(a, b, chunk=128), a, b)
    report("kernels/rglru_pallas_interp_512", t_p, "interpret mode")

    from repro.models.ssm import init_ssm, ssm_forward
    p_ssm = init_ssm(jax.random.fold_in(key, 4), 64, d_state=32,
                     head_dim=16, expand=2, conv_width=4,
                     dtype=jnp.float32)
    xs = jax.random.normal(jax.random.fold_in(key, 5), (2, 512, 64))
    fwd = lambda impl: jax.jit(lambda x: ssm_forward(    # noqa: E731
        p_ssm, x, d_state=32, head_dim=16, expand=2, chunk=64,
        impl=impl))
    t_j = _time(fwd("jnp"), xs)
    report("kernels/ssd_jnp_512", t_j, "chunked dual form, per-head map")
    t_sp = _time(fwd("pallas"), xs)
    report("kernels/ssd_pallas_interp_512", t_sp, "interpret mode")
