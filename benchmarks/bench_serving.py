"""Serving: continuous batching vs the static one-shot serve path.

One heterogeneous trace (openvid prompt lengths, geometric output
lengths) served two ways on the same engine:

  * continuous — ServingEngine: iteration-level batching, DHP-planned
    chunked prefill, paged KV slots, bucketed executables;
  * static     — Engine.serve per fixed batch: prompts padded to the
    batch max, every stream decoded until the LONGEST request finishes
    (the batch-synchronous pathology continuous batching removes).

Throughput counts only *useful* tokens (what each request asked for),
so the static path pays for its padded prefill and wasted decode steps.
Both paths are measured warm (a first pass populates the executable
pool) — the steady-state comparison, not a compile-time race.

Same workload in smoke and full runs so CI tracks one trajectory; the
`serving/continuous/schedule_ms` row feeds the check_regression gate
alongside the training scheduling-latency rows.
"""
from __future__ import annotations

import time

import numpy as np

SLOTS = 4


def _engine_and_trace():
    from repro.api import Engine, sample_trace
    engine = Engine("internvl3-2b", strategy="dhp", reduced=True, seed=0)
    rng = np.random.default_rng(0)
    trace = sample_trace(
        "openvid", 10, rng, vocab=engine.cfg.vocab, max_prompt=48,
        min_prompt=4, mean_new_tokens=6, max_new_tokens=12)
    return engine, trace


def _run_static(engine, trace):
    """Arrival-order batches of SLOTS through the one-shot path;
    returns (useful_tokens, wall_s)."""
    import jax
    useful, t0 = 0, time.perf_counter()
    for i in range(0, len(trace), SLOTS):
        batch = trace[i:i + SLOTS]
        S = max(r.prompt_len for r in batch)
        gen = max(r.max_new_tokens for r in batch)
        prompts = np.zeros((len(batch), S), np.int32)
        for r_i, r in enumerate(batch):
            prompts[r_i, :r.prompt_len] = r.tokens
        out, _ = engine.serve(prompts=prompts, gen_tokens=gen)
        jax.block_until_ready(out)
        useful += sum(r.max_new_tokens for r in batch)
    return useful, time.perf_counter() - t0


def run(report, smoke: bool = False) -> None:
    engine, trace = _engine_and_trace()

    srv = engine.serving(slots=SLOTS, prefill_chunk=16)
    srv.run(trace)                       # warm the executable pool
    rep = srv.run(trace)                 # measured, steady state

    _run_static(engine, trace)           # warm the one-shot pool keys
    static_tokens, static_wall = _run_static(engine, trace)
    static_tps = static_tokens / max(static_wall, 1e-9)
    speedup = rep.tokens_per_s / max(static_tps, 1e-9)

    report("serving/continuous/us_per_token",
           1e6 / max(rep.tokens_per_s, 1e-9),
           f"tokens_per_s={rep.tokens_per_s:.1f} "
           f"ttft_mean={rep.mean_ttft_s * 1e3:.1f}ms "
           f"decode_steps={rep.n_decode_steps} "
           f"prefill_chunks={rep.n_prefill_chunks} "
           f"exe_misses={rep.exe_misses} "
           f"kv_peak={rep.peak_kv_blocks}blk")
    report("serving/static/us_per_token",
           1e6 / max(static_tps, 1e-9),
           f"tokens_per_s={static_tps:.1f} "
           f"(eager padded prefill, decode to batch max)")
    report("serving/continuous_vs_static_speedup", speedup * 1e6,
           f"speedup={speedup:.2f}x on useful tokens/s "
           f"({len(trace)} requests, {SLOTS} slots)")
    # host planning latency of the serving scheduler — the serving
    # analogue of the fig4 */schedule_ms rows; same CI gate
    report("serving/continuous/schedule_ms",
           rep.schedule_ms / max(rep.n_iterations, 1) * 1e3,
           f"value = us of prefill planning per iteration "
           f"(plan_cache={rep.plan_cache})")
    engine.close()


def run_smoke(report) -> None:
    run(report, smoke=True)
