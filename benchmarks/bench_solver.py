"""Tables 1 & 2 — scheduling / solver time vs GBS and vs rank count —
plus the Stage-2 allocator implementation sweep (PR 7).

Paper: solver <= 86 ms (GBS=512, N=64); schedule < 1 s; both << the
global-batch compute time.

`solver_sweep` compares the three Stage-2 implementations on identical
instances — `allocate_reference` (the original pure-Python DP, kept
verbatim), `allocate` (vectorized cost table + Hankel-view DP rows) and
`IncrementalAllocator` (vectorized + cross-batch warm starts on a
perturbed-batch stream) — over K' in {64, 256, 512} x N in {8, 64}.
Groups beyond one wave's rank budget (sum d_min <= N) are split with
the scheduler's wave partitioner, exactly as DHPScheduler would run
them, and every implementation is certified to return bit-identical
degrees before its timing row is reported.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (CostModel, DHPScheduler, IncrementalAllocator,
                        allocate, allocate_reference, analytic_coeffs,
                        pack_sequences, sample_batch)
from repro.core.scheduler import _feasible_waves

CM = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                               kv_heads=4, ffn=18944, vocab=152000))
BUDGET = 8e9


def table1_vs_gbs(n_ranks: int = 64, seed: int = 0):
    rows = []
    rng = np.random.default_rng(seed)
    for gbs in (128, 256, 512):
        seqs = sample_batch("openvid", gbs, rng, max_tokens=262144)
        sched = DHPScheduler(CM, n_ranks, BUDGET)
        plan = sched.schedule(seqs)
        rows.append({
            "gbs": gbs,
            "computing_time_s": plan.total_time_est,
            "schedule_time_ms": plan.schedule_ms,
            "solver_time_ms": plan.solver_ms,
        })
    return rows


def table2_vs_ranks(gbs: int = 512, seed: int = 0):
    rows = []
    rng = np.random.default_rng(seed)
    seqs = sample_batch("openvid", gbs, rng, max_tokens=262144)
    for n in (16, 32, 64):
        sched = DHPScheduler(CM, n, BUDGET)
        plan = sched.schedule(seqs)
        rows.append({
            "ranks": n,
            "computing_time_s": plan.total_time_est,
            "schedule_time_ms": plan.schedule_ms,
            "solver_time_ms": plan.solver_ms,
        })
    return rows


def _unit_groups(kprime, n_ranks, rng):
    """K' single-sequence atomic groups with memory-derived d_min
    (clamped to N so every instance is feasible)."""
    import math

    from repro.core import AtomicGroup

    seqs = sample_batch("openvid", kprime, rng, max_tokens=65536)
    c = CM.coeffs
    e_act = BUDGET - c.m_ms
    groups = []
    for s in seqs:
        need = s.length * c.m_token
        d_min = max(1, min(n_ranks, math.ceil(need / e_act)))
        groups.append(AtomicGroup(seqs=[s], d_min=d_min,
                                  capacity=d_min * e_act, used=need))
    return groups


def _perturbed(waves):
    """Suffix-perturb each wave: bump the LAST group's sequence length
    by one token (same d_min, so the rank total — and with it every
    earlier DP row — stays warm-start-reusable)."""
    import dataclasses

    from repro.core import AtomicGroup

    out = []
    for w in waves:
        w2 = list(w)
        g = w2[-1]
        s = dataclasses.replace(g.seqs[0], length=g.seqs[0].length + 1)
        w2[-1] = AtomicGroup(seqs=[s] + list(g.seqs[1:]), d_min=g.d_min,
                             capacity=g.capacity, used=g.used)
        out.append(w2)
    return out


def solver_sweep(report, *, kprimes=(64, 256, 512), ranks=(8, 64),
                 repeats=3, stream=8, seed=0):
    """Time the three Stage-2 implementations on an alternating stream
    of `stream` (original | suffix-perturbed) instances — the
    incremental allocator's intended consecutive-batch workload — and
    certify bit-identical degrees against the legacy solver."""
    tf = CM.group_time
    for n in ranks:
        for kp in kprimes:
            rng = np.random.default_rng(seed)
            waves = _feasible_waves(_unit_groups(kp, n, rng), n)
            waves_b = _perturbed(waves)

            def run_stream(solve):
                t0 = time.perf_counter()
                out = []
                for i in range(stream):
                    ws = waves if i % 2 == 0 else waves_b
                    out.append([solve(w) for w in ws])
                return time.perf_counter() - t0, out

            best, outs = {}, {}
            inc = IncrementalAllocator()
            impls = (("legacy", lambda w: allocate_reference(w, n, tf)),
                     ("vec", lambda w: allocate(w, n, tf)),
                     ("inc", lambda w: inc(w, n, tf)))
            for name, solve in impls:
                b = float("inf")
                for _ in range(repeats):
                    dt, out = run_stream(solve)
                    b = min(b, dt)
                best[name], outs[name] = b, out
            same = all(
                a.degrees == r.degrees and a.makespan == r.makespan
                for impl in ("vec", "inc")
                for sa, sr in zip(outs[impl], outs["legacy"])
                for a, r in zip(sa, sr))
            n_solves = stream * len(waves)
            us = {k: v / n_solves * 1e6 for k, v in best.items()}
            tag = f"solver/sweep_k{kp}_n{n}"
            report(f"{tag}/legacy_us", us["legacy"],
                   f"waves={len(waves)} per-DP-solve us, pure-Python")
            report(f"{tag}/vec_us", us["vec"],
                   f"speedup={us['legacy'] / max(us['vec'], 1e-9):.1f}x "
                   f"identical={same}")
            report(f"{tag}/inc_us", us["inc"],
                   f"speedup={us['legacy'] / max(us['inc'], 1e-9):.1f}x "
                   f"warm-start stream identical={same}")


def run(report):
    for r in table1_vs_gbs():
        report(f"table1/solver_gbs{r['gbs']}", r["solver_time_ms"] * 1e3,
               f"schedule={r['schedule_time_ms']:.0f}ms "
               f"compute={r['computing_time_s']:.2f}s "
               f"overlap_ok={r['schedule_time_ms'] / 1e3 < r['computing_time_s']}")
    for r in table2_vs_ranks():
        report(f"table2/solver_n{r['ranks']}", r["solver_time_ms"] * 1e3,
               f"schedule={r['schedule_time_ms']:.0f}ms "
               f"compute={r['computing_time_s']:.2f}s")
    solver_sweep(report)


def run_smoke(report):
    """CI subset: one K' per rank count, short stream."""
    solver_sweep(report, kprimes=(64,), ranks=(8, 64), repeats=2,
                 stream=4)
