"""Tables 1 & 2 — scheduling / solver time vs GBS and vs rank count.

Paper: solver <= 86 ms (GBS=512, N=64); schedule < 1 s; both << the
global-batch compute time.
"""
from __future__ import annotations

import numpy as np

from repro.core import (CostModel, DHPScheduler, analytic_coeffs,
                        sample_batch)

CM = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                               kv_heads=4, ffn=18944, vocab=152000))
BUDGET = 8e9


def table1_vs_gbs(n_ranks: int = 64, seed: int = 0):
    rows = []
    rng = np.random.default_rng(seed)
    for gbs in (128, 256, 512):
        seqs = sample_batch("openvid", gbs, rng, max_tokens=262144)
        sched = DHPScheduler(CM, n_ranks, BUDGET)
        plan = sched.schedule(seqs)
        rows.append({
            "gbs": gbs,
            "computing_time_s": plan.total_time_est,
            "schedule_time_ms": plan.schedule_ms,
            "solver_time_ms": plan.solver_ms,
        })
    return rows


def table2_vs_ranks(gbs: int = 512, seed: int = 0):
    rows = []
    rng = np.random.default_rng(seed)
    seqs = sample_batch("openvid", gbs, rng, max_tokens=262144)
    for n in (16, 32, 64):
        sched = DHPScheduler(CM, n, BUDGET)
        plan = sched.schedule(seqs)
        rows.append({
            "ranks": n,
            "computing_time_s": plan.total_time_est,
            "schedule_time_ms": plan.schedule_ms,
            "solver_time_ms": plan.solver_ms,
        })
    return rows


def run(report):
    for r in table1_vs_gbs():
        report(f"table1/solver_gbs{r['gbs']}", r["solver_time_ms"] * 1e3,
               f"schedule={r['schedule_time_ms']:.0f}ms "
               f"compute={r['computing_time_s']:.2f}s "
               f"overlap_ok={r['schedule_time_ms'] / 1e3 < r['computing_time_s']}")
    for r in table2_vs_ranks():
        report(f"table2/solver_n{r['ranks']}", r["solver_time_ms"] * 1e3,
               f"schedule={r['schedule_time_ms']:.0f}ms "
               f"compute={r['computing_time_s']:.2f}s")
