"""Figures 4 & 6 — simulated end-to-end iteration time + speedups per
(model x dataset). Paper: 1.14x-1.36x over the best static baseline,
largest on OpenVid / 8B models.
"""
from __future__ import annotations

from repro.core import CostModel, analytic_coeffs, end_to_end_table

# paper Table 5 (Appendix A.1) — all six evaluated models
MODELS = {
    "internvl3-2b": dict(hidden=1536, n_layers=28, n_heads=12, kv_heads=2,
                         ffn=8960, vocab=151674),
    "internvl2.5-4b": dict(hidden=2048, n_layers=36, n_heads=16,
                           kv_heads=8, ffn=11008, vocab=151674),
    "internvl3-8b": dict(hidden=3584, n_layers=28, n_heads=28, kv_heads=4,
                         ffn=18944, vocab=151674),
    "qwen3vl-2b": dict(hidden=2048, n_layers=28, n_heads=16, kv_heads=8,
                       ffn=6144, vocab=151674),
    "qwen3vl-4b": dict(hidden=2560, n_layers=36, n_heads=32, kv_heads=8,
                       ffn=9728, vocab=151674),
    "qwen3vl-8b": dict(hidden=4096, n_layers=36, n_heads=32, kv_heads=8,
                       ffn=12288, vocab=151674),
}


def run(report):
    for name, kw in MODELS.items():
        cm = CostModel(analytic_coeffs(**kw))
        rows = end_to_end_table(cm, n_ranks=64, mem_budget=8e9, gbs=512,
                                iters=3, max_tokens=262144)
        for r in rows:
            report(f"fig4/{name}/{r['dataset']}",
                   r["dhp_s"] * 1e6,
                   f"faithful_speedup="
                   f"{r['speedup_faithful_vs_best_static']:.2f}x "
                   f"optimized_speedup={r['speedup_vs_best_static']:.2f}x "
                   f"megatron={r['megatron_s']:.2f}s "
                   f"deepspeed={r['deepspeed_s']:.2f}s")
