"""Figures 4 & 6 — simulated end-to-end iteration time + speedups per
(model x dataset). Paper: 1.14x-1.36x over the best static baseline,
largest on OpenVid / 8B models.

One code path for every row: each scheduling policy is pulled from the
`repro.api` strategy registry, bound to the same cost model, and its
strategy-attributed ExecutionPlans are aggregated into a per-strategy
comparison table (iteration time, scheduling latency and its per-stage
split). Adding a policy to the comparison = adding its registry name.
"""
from __future__ import annotations

import numpy as np

from repro.api import get_strategy
from repro.core import CostModel, analytic_coeffs, sample_batch

# paper Table 5 (Appendix A.1) — all six evaluated models
MODELS = {
    "internvl3-2b": dict(hidden=1536, n_layers=28, n_heads=12, kv_heads=2,
                         ffn=8960, vocab=151674),
    "internvl2.5-4b": dict(hidden=2048, n_layers=36, n_heads=16,
                           kv_heads=8, ffn=11008, vocab=151674),
    "internvl3-8b": dict(hidden=3584, n_layers=28, n_heads=28, kv_heads=4,
                         ffn=18944, vocab=151674),
    "qwen3vl-2b": dict(hidden=2048, n_layers=28, n_heads=16, kv_heads=8,
                       ffn=6144, vocab=151674),
    "qwen3vl-4b": dict(hidden=2560, n_layers=36, n_heads=32, kv_heads=8,
                       ffn=9728, vocab=151674),
    "qwen3vl-8b": dict(hidden=4096, n_layers=36, n_heads=32, kv_heads=8,
                       ffn=12288, vocab=151674),
}

# the evaluated scheduling policies, by registry name
STRATEGIES = ("dhp", "dhp-faithful", "megatron", "deepspeed")
STATIC = ("megatron", "deepspeed")

#: when set (benchmarks/run.py --trace PATH), run_trace_overhead saves
#: the traced smoke-train's Chrome trace JSON here — the CI artifact.
TRACE_OUT = None


def strategy_table(cost_model: CostModel, *, n_ranks: int,
                   mem_budget: float, datasets, gbs: int, iters: int,
                   seed: int = 0, max_tokens=None,
                   strategies=STRATEGIES):
    """Plan `iters` sampled batches per dataset with every strategy;
    returns {dataset: {strategy: {time_s, schedule_ms, stage_ms}}}."""
    rng = np.random.default_rng(seed)
    strats = {name: get_strategy(name).bind(cost_model, n_ranks,
                                            mem_budget)
              for name in strategies}
    table = {}
    for ds in datasets:
        acc = {name: {"time_s": 0.0, "schedule_ms": 0.0, "stage_ms": {}}
               for name in strategies}
        for _ in range(iters):
            seqs = sample_batch(ds, gbs, rng, max_tokens=max_tokens)
            for name, strat in strats.items():
                plan = strat.plan(seqs)
                assert plan.strategy_name == name
                acc[name]["time_s"] += plan.total_time_est / iters
                acc[name]["schedule_ms"] += plan.schedule_ms / iters
                for k, v in plan.stage_ms.items():
                    acc[name]["stage_ms"][k] = (
                        acc[name]["stage_ms"].get(k, 0.0) + v / iters)
        table[ds] = acc
    return table


def run_packed(report):
    """Packed-varlen vs per-sequence-padded EXECUTION on host devices:
    padding efficiency and executable-compilation counts for the same
    heterogeneous plan (the acceptance metrics of ISSUE 2). Unlike the
    simulated fig4 rows these numbers come from DHPExecutor.run_plan.
    Same workload in smoke and full runs so CI tracks one trajectory."""
    import dataclasses
    import time

    import jax

    from repro.configs import get_config
    from repro.core import CostModel, DHPScheduler, analytic_coeffs
    from repro.core.executor import DHPExecutor
    from repro.core.group_pool import GroupPool
    from repro.data.pipeline import HeterogeneousLoader
    from repro.models.model import init_params

    cfg = get_config("internvl3-2b").reduced().with_(family="dense",
                                                     vlm=None)
    params = init_params(jax.random.PRNGKey(0), cfg)
    # gbs=24/seed=5 yields 6 heterogeneous groups -> 6 per-seq
    # executables vs 2 packed (n_seqs gone from the key space)
    gbs = 24
    loader = HeterogeneousLoader("openvid", gbs, cfg.vocab, seed=5,
                                 max_tokens=700, tokens_per_frame=16)
    data = next(iter(loader))
    coeffs = dataclasses.replace(
        analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                        n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                        ffn=cfg.d_ff, vocab=cfg.vocab),
        m_ms=0.0, m_token=1.0)
    plan = DHPScheduler(CostModel(coeffs), 1,
                        mem_budget=1200.0).schedule(data.infos)

    rows = {}
    for name, packed, ladder in (("packed", True, "mult256"),
                                 ("perseq", False, "pow2")):
        pool = GroupPool(jax.devices(), bucket_fn=ladder)
        ex = DHPExecutor(cfg, pool=pool, packed=packed)
        t0 = time.perf_counter()
        loss, _ = jax.block_until_ready(ex.run_plan(params, plan, data))
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(ex.run_plan(params, plan, data))
        warm = time.perf_counter() - t0
        st = ex.last_run_stats
        rows[name] = dict(st, cold_s=cold, warm_s=warm,
                          exe_total=pool.stats.exe_misses)
        report(f"packed_exec/{name}/padding_efficiency",
               st["padding_efficiency"] * 100,
               f"real={st['real_tokens']} padded={st['padded_tokens']} "
               f"(value = percent)")
        report(f"packed_exec/{name}/exe_misses",
               pool.stats.exe_misses,
               f"{plan.n_groups} groups, ladder={ladder}, "
               f"warm-step exe_misses=0")
        report(f"packed_exec/{name}/step_time", warm * 1e6,
               f"warm step; cold(+compile)={cold:.1f}s "
               f"loss={float(loss):.3f}")
    over_p = rows["packed"]["padded_tokens"] - rows["packed"]["real_tokens"]
    over_u = rows["perseq"]["padded_tokens"] - rows["perseq"]["real_tokens"]
    report("packed_exec/overhead_reduction",
           (1 - over_p / max(over_u, 1)) * 100,
           f"padded-token overhead {over_u} -> {over_p} "
           f"(value = percent; target >= 30)")
    report("packed_exec/exe_reduction",
           rows["perseq"]["exe_total"] / max(rows["packed"]["exe_total"], 1),
           f"executables {rows['perseq']['exe_total']} -> "
           f"{rows['packed']['exe_total']} (value = factor; target >= 2)")


def run_lookahead(report):
    """ISSUE-3 acceptance case: the pipelined lookahead planner (plan
    cache + background planning thread) vs the synchronous planner on a
    REPEATED-SHAPE heterogeneous stream, measured wall-clock on host
    devices through Engine.train. Reports per-step wall time for both
    paths, plan_cache_hit counts, hidden planning ms and group
    reconfigurations — the telemetry that attributes the win.

    Per-step wall is the MEDIAN of (execute time + un-hidden planner
    stall) over the measured steps: total-wall / steps was observed to
    flip winner on identical code because ONE noisy device step (~5 s
    of compute vs ~1 ms of scheduling on this host) swamped the
    scheduling difference the row exists to measure; the median of the
    per-step sums is outlier-robust and isolates exactly the quantity
    lookahead changes."""
    import statistics

    from repro.api import ClusterSpec, Engine, get_strategy
    from repro.configs import get_config
    from repro.data.pipeline import HeterogeneousLoader

    # Tiny model so host scheduling is a visible share of the step; a
    # stream cycling 3 distinct batch shapes so the plan cache can hit.
    cfg = get_config("internvl3-2b").reduced().with_(
        family="dense", vlm=None, d_model=64, n_heads=4, kv_heads=2,
        d_ff=256, vocab=512, n_layers=2)
    base = HeterogeneousLoader("openvid", 24, cfg.vocab, seed=7,
                               max_tokens=450, tokens_per_frame=16)
    shapes = [next(base) for _ in range(3)]
    warm, measured = 3, 9          # warmup covers ALL 3 batch shapes
    stream = [shapes[i % len(shapes)] for i in range(warm + measured)]

    rows = {}
    for mode, lookahead, cache in (("pipelined", True, True),
                                   ("sync", False, False)):
        cluster = ClusterSpec.auto(mem_budget=500.0)
        eng = Engine(cfg, cluster, seed=0,
                     strategy=get_strategy("dhp", plan_cache=cache))
        eng.train(loader=iter(stream[:warm]), steps=warm,
                  lookahead=lookahead)            # compile warmup
        hist = eng.train(loader=iter(stream[warm:]), steps=measured,
                         lookahead=lookahead)
        # planning latency the devices actually WAIT for — the
        # schedule-hiding metric (sync pays all of schedule_ms;
        # the pipeline pays only the non-overlapped remainder)
        stalls = [m.schedule_ms - m.plan_overlap_ms for m in hist]
        wall = statistics.median(
            m.step_time_s + s / 1e3 for m, s in zip(hist, stalls))
        sched = sum(m.schedule_ms for m in hist) / len(hist)
        overlap = sum(m.plan_overlap_ms for m in hist) / len(hist)
        rows[mode] = dict(
            wall_s=wall,
            stall_ms=sum(stalls) / len(stalls),
            cache_hits=sum(m.plan_cache_hit for m in hist),
            reconf=sum(m.groups_reconfigured for m in hist))
        report(f"lookahead/{mode}/step_wall", wall * 1e6,
               f"sched={sched:.2f}ms overlap={overlap:.2f}ms "
               f"cache_hits={rows[mode]['cache_hits']}/{len(hist)} "
               f"reconf={rows[mode]['reconf']}")
        report(f"lookahead/{mode}/plan_stall", rows[mode]["stall_ms"]
               * 1e3, "us of planning NOT hidden behind execution")
        eng.close()
    report("lookahead/plan_cache_hits", rows["pipelined"]["cache_hits"],
           f"of {measured} steps (target > 0)")
    report("lookahead/speedup",
           rows["sync"]["wall_s"] / max(rows["pipelined"]["wall_s"],
                                        1e-12),
           f"sync wall / pipelined wall per step (target > 1.0); "
           f"schedule-hiding "
           f"{rows['sync']['stall_ms'] / max(rows['pipelined']['stall_ms'], 1e-9):.1f}x"
           f" on the plan-stall component")


def run_modality_mix(report):
    """ISSUE-5 sweep: the SAME length histogram planned under different
    modality mixes (pure text, interleaved frames, monolithic
    vision-prefix blocks). The derived-eta cost model must price the
    mixes apart — the planner-visible signal the scalar eta hack
    collapsed — and the span-aware PlanCache must key them apart.
    Planning cost is reported as */plan_us (NOT */schedule_ms: this
    sweep plans far bigger batches than the fig4 smoke rows, and the
    suffix rows feed the regression gate's median — mixing populations
    would break the BENCH_*.json trajectory)."""
    import numpy as np

    from repro.api import get_strategy
    from repro.core import (MMSequence, ModalitySpan, PlanCache,
                            analytic_coeffs, sample_mm_batch)

    cm = CostModel(analytic_coeffs(**MODELS["internvl3-2b"]))
    rng = np.random.default_rng(11)
    base = sample_mm_batch("openvid", 64, rng, max_tokens=65536)

    def remix(mm, style):
        spans, off, sid = [], 0, mm.seq_id
        vis = sum(s.length for s in mm.spans
                  if s.attn == "bidirectional")
        L = mm.length
        if style == "text" or vis == 0:
            spans = [ModalitySpan("text", 0, L)]
        elif style == "prefix":
            spans = [ModalitySpan("vision", 0, vis, "bidirectional"),
                     ModalitySpan("text", vis, L - vis)]
        else:                      # interleaved: the sampled layout
            return mm
        return MMSequence(spans=tuple(spans), seq_id=sid)

    rows = {}
    for style in ("text", "interleaved", "prefix"):
        batch = [remix(m, style) for m in base]
        strat = get_strategy("dhp").bind(cm, 64, 8e9)
        plan = strat.plan(batch)
        eta = sum(m.eta * m.length for m in batch) / \
            sum(m.length for m in batch)
        rows[style] = plan.total_time_est
        report(f"modality_mix/{style}", plan.total_time_est * 1e6,
               f"token-weighted derived eta={eta:.3f} "
               f"degrees={plan.degree_histogram}")
        report(f"modality_mix/{style}/plan_us",
               plan.schedule_ms * 1e3,
               "value = us of host scheduling per span-bearing batch")
    assert rows["text"] <= rows["interleaved"] <= rows["prefix"], rows
    # span-aware PlanCache: identical length histograms, different
    # layouts -> different keys (no false hits across mixes)
    cache = PlanCache()
    keys = {style: cache.key([remix(m, style).seq_info for m in base])
            for style in ("text", "interleaved", "prefix")}
    assert len(set(keys.values())) == 3, keys
    report("modality_mix/eta_cost_spread",
           rows["prefix"] / rows["text"],
           "prefix-vision vs pure-text iteration-time factor at EQUAL "
           "lengths (value = factor; >1 means structure is priced)")


def run_trace_overhead(report):
    """ISSUE-9 acceptance rows: tracing must be ~free.

    A/B: the SAME planning workload (fig4-style batches, cache-less dhp
    strategy, one instance per arm) with tracing disabled vs a live
    Tracer installed. The arms are interleaved per batch and each
    batch's cost taken as the MIN over repeats — host contention was
    observed to swing a median-of-sequential-arms ratio 0.93-1.41 on
    identical code, while the min of a fixed workload isolates the
    deterministic cost the tracer actually adds. `trace/overhead` is
    the traced/untraced ratio of summed per-batch minima —
    check_regression gates it at `--trace-tolerance` (default 1.05 =
    the <=5% overhead budget). gbs=256 so per-plan work is
    milliseconds and the ~constant handful of span events per plan is
    measured against a realistic denominator.

    Also runs the tiny traced Engine.train (run_lookahead's model) so
    every CI run produces and schema-validates a real trace + run
    report; the trace JSON lands at TRACE_OUT when run.py --trace set
    it (the uploaded CI artifact)."""
    import time

    from repro.obs import NULL_TRACER, Tracer, tracing, validate_trace

    cm = CostModel(analytic_coeffs(**MODELS["internvl3-2b"]))
    rng = np.random.default_rng(23)
    batches = [sample_batch("openvid", 256, rng, max_tokens=262144)
               for _ in range(6)]

    arms = {"untraced": (NULL_TRACER,
                         get_strategy("dhp",
                                      plan_cache=False).bind(cm, 64,
                                                             8e9)),
            "traced": (Tracer(),
                       get_strategy("dhp",
                                    plan_cache=False).bind(cm, 64,
                                                           8e9))}
    mins = {name: [float("inf")] * len(batches) for name in arms}
    for name, (tracer, strat) in arms.items():  # warmup pass
        with tracing(tracer):
            for b in batches:
                strat.plan(b)
    order = list(arms.items())
    for rep in range(6):
        # whichever arm runs first in a pair was measured ~5% slower
        # with tracing OFF in both (cache position bias): alternate the
        # order so each arm's min sees the fast position
        for i, b in enumerate(batches):
            for name, (tracer, strat) in (
                    order if rep % 2 == 0 else order[::-1]):
                with tracing(tracer):
                    t0 = time.perf_counter()
                    strat.plan(b)
                    dt = time.perf_counter() - t0
                mins[name][i] = min(mins[name][i], dt)
    untraced = sum(mins["untraced"]) / len(batches) * 1e6
    traced = sum(mins["traced"]) / len(batches) * 1e6
    overhead = traced / max(untraced, 1e-9)
    n_captured = len(arms["traced"][0].to_json()["traceEvents"])
    report("trace/untraced_us", untraced,
           "mean of per-batch min plan wall, tracing disabled")
    report("trace/traced_us", traced,
           f"mean of per-batch min plan wall under a live Tracer "
           f"({n_captured} events captured)")
    report("trace/overhead", overhead,
           "traced/untraced ratio (value = factor; gated <= "
           "--trace-tolerance, default 1.05)")

    # -- traced smoke train: produce + validate the CI trace artifact --
    from repro.api import ClusterSpec, Engine, get_strategy as _gs
    from repro.configs import get_config
    from repro.data.pipeline import HeterogeneousLoader

    cfg = get_config("internvl3-2b").reduced().with_(
        family="dense", vlm=None, d_model=64, n_heads=4, kv_heads=2,
        d_ff=256, vocab=512, n_layers=2)
    loader = HeterogeneousLoader("openvid", 16, cfg.vocab, seed=9,
                                 max_tokens=450, tokens_per_frame=16)
    eng = Engine(cfg, ClusterSpec.auto(mem_budget=500.0), seed=0,
                 strategy=_gs("dhp"))
    run_tracer = Tracer()
    eng.train(loader=iter(loader), steps=4, lookahead=True,
              trace=run_tracer, report=True)
    obj = run_tracer.to_json()
    n_events = validate_trace(obj)              # raises on bad schema
    rep = eng.last_report
    report("trace/smoke_events", n_events,
           f"schema-valid Chrome trace events from a 4-step traced "
           f"train on {eng.cluster.n_replicas} host devices")
    report("trace/smoke_mape_pct", rep.model_error["mape_pct"],
           f"cost-model MAPE over {rep.model_error['n_samples']} "
           f"measured groups (run report)")
    if TRACE_OUT:
        run_tracer.save(TRACE_OUT)
        rep.save(TRACE_OUT + ".report.json")
        report("trace/artifact", float(n_events),
               f"saved {TRACE_OUT} (+ .report.json)")
    eng.close()


def run(report, smoke: bool = False):
    models = (dict(list(MODELS.items())[:1]) if smoke else MODELS)
    # smoke averages over 3 sampled batches too: the */schedule_ms rows
    # feed the CI regression gate, and single-sample planning latencies
    # were noisy enough to flip the gate on identical code
    iters = 3
    gbs = 64 if smoke else 512
    datasets = ("openvid",) if smoke else ("msrvtt", "internvid",
                                           "openvid")
    for name, kw in models.items():
        cm = CostModel(analytic_coeffs(**kw))
        table = strategy_table(cm, n_ranks=64, mem_budget=8e9,
                               datasets=datasets, gbs=gbs, iters=iters,
                               max_tokens=262144)
        for ds, acc in table.items():
            best_static = min(acc[s]["time_s"] for s in STATIC)
            for sname in STRATEGIES:
                r = acc[sname]
                stages = " ".join(f"{k}={v:.1f}ms"
                                  for k, v in r["stage_ms"].items())
                report(f"fig4/{name}/{ds}/{sname}",
                       r["time_s"] * 1e6,
                       f"speedup_vs_best_static="
                       f"{best_static / r['time_s']:.2f}x "
                       f"sched={r['schedule_ms']:.1f}ms {stages}")
                # dedicated scheduling-latency row: the CI regression
                # gate (benchmarks/check_regression.py) takes the median
                # over every */schedule_ms row and compares it against
                # the committed BENCH_*.json baseline.
                report(f"fig4/{name}/{ds}/{sname}/schedule_ms",
                       r["schedule_ms"] * 1e3,
                       "value = us of host scheduling per batch")
                if sname in ("dhp", "dhp-faithful"):
                    # Stage-2 allocator time per batch (cost table +
                    # DP). check_regression gates the MEDIAN of every
                    # */allocate_us row against the committed baseline
                    # — the millisecond-class-planning budget of PR 7.
                    report(f"fig4/{name}/{ds}/{sname}/allocate_us",
                           r["stage_ms"].get("allocate", 0.0) * 1e3,
                           f"cost={r['stage_ms'].get('allocate_cost', 0.0) * 1e3:.0f}us "
                           f"dp={r['stage_ms'].get('allocate_dp', 0.0) * 1e3:.0f}us")
    run_packed(report)
    run_lookahead(report)
    run_modality_mix(report)
    run_trace_overhead(report)


def run_smoke(report):
    """CI perf canary: one model x one dataset x every strategy, plus
    the packed-vs-padded executor comparison."""
    run(report, smoke=True)
