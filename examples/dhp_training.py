"""End-to-end DHP training driver — the paper's system running for real.

Heterogeneous video-length batches -> async DHP scheduler (BFD packing +
2D-DP) -> executor dispatching Ring-CP groups over 8 host devices, with
group/executable pooling. Compares against the static baseline and
prints the per-step degree histograms (the Table-4 view, live).

  python examples/dhp_training.py --steps 30
  python examples/dhp_training.py --steps 300 --d-model 512 --layers 12

(~100M-param invocation:
  python examples/dhp_training.py --d-model 768 --layers 12 \\
      --vocab 32000 --steps 200  — slower on CPU, same code path.)
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax           # noqa: E402

from repro.configs import get_config                     # noqa: E402
from repro.core import (CostModel, DHPScheduler,
                        analytic_coeffs)                 # noqa: E402
from repro.core.executor import DHPExecutor              # noqa: E402
from repro.core.scheduler import static_plan             # noqa: E402
from repro.data.pipeline import HeterogeneousLoader      # noqa: E402
from repro.models.model import init_params               # noqa: E402
from repro.training.optimizer import (AdamW,
                                      cosine_schedule)   # noqa: E402
from repro.training.train_step import TrainState         # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--gbs", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=512)
    ap.add_argument("--mem-budget", type=float, default=900.0,
                    help="per-rank activation budget (tokens)")
    ap.add_argument("--dataset", default="openvid")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--compare-static", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(family="dense", vlm=None)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    n_heads=max(4, args.d_model // 64), kv_heads=2,
                    d_ff=args.d_model * 4)
    if args.layers:
        over["n_layers"] = args.layers
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = cfg.with_(**over)
    n_ranks = len(jax.devices())
    print(f"devices={n_ranks} arch={cfg.arch_id} L={cfg.n_layers} "
          f"d={cfg.d_model}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"params: {sum(p.size for p in jax.tree.leaves(params))/1e6:.1f}M")
    opt = AdamW(lr=cosine_schedule(3e-4, 10, args.steps))
    state = TrainState(params, opt.init(params))

    coeffs = dataclasses.replace(
        analytic_coeffs(hidden=cfg.d_model, n_layers=cfg.n_layers,
                        n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                        ffn=cfg.d_ff, vocab=cfg.vocab),
        m_ms=0.0, m_token=1.0)
    cm = CostModel(coeffs)
    sched = DHPScheduler(cm, n_ranks, mem_budget=args.mem_budget)
    ex = DHPExecutor(cfg)

    @jax.jit
    def apply_update(state, grads):
        p, o = opt.update(grads, state.opt, state.params)
        return TrainState(p, o)

    loader = iter(HeterogeneousLoader(
        args.dataset, args.gbs, cfg.vocab, seed=0,
        max_tokens=args.max_tokens, tokens_per_frame=16))
    data = next(loader)
    sched.prepare(data.infos)           # async scheduling (paper §5 (2))

    t_start = time.perf_counter()
    for i in range(args.steps):
        plan = sched.collect()
        nxt = next(loader)
        sched.prepare(nxt.infos)        # overlap planning w/ compute
        t0 = time.perf_counter()
        loss, grads = ex.run_plan(state.params, plan, data)
        state = apply_update(state, grads)
        dt = time.perf_counter() - t0
        print(f"step {i:3d} loss={float(loss):.4f} "
              f"degrees={plan.degree_histogram} "
              f"sched={plan.schedule_ms:.1f}ms step={dt:.2f}s")
        if args.compare_static and i == 0:
            splan = static_plan(data.infos, cm, n_ranks, args.mem_budget)
            sl, _ = ex.run_plan(state.params, splan, data)
            print(f"   static-baseline loss={float(sl):.4f} "
                  f"est {splan.total_time_est:.3f}s "
                  f"vs dhp est {plan.total_time_est:.3f}s")
        data = nxt
    total = time.perf_counter() - t_start
    print(f"\n{args.steps} steps in {total:.1f}s; "
          f"executable pool: {ex.pool.stats}")


if __name__ == "__main__":
    main()
