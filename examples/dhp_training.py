"""End-to-end DHP training driver — the paper's system running for real,
now expressed entirely through the `repro.api` Engine.

Heterogeneous video-length batches -> async Strategy planning (BFD
packing + 2D-DP on a host thread) -> executor dispatching Ring-CP groups
over 8 host devices, with group/executable pooling. `--compare-static`
re-plans the first batch with the static baseline strategy from the same
registry and runs it through the same executor.

  python examples/dhp_training.py --steps 30
  python examples/dhp_training.py --steps 300 --d-model 512 --layers 12

(~100M-param invocation:
  python examples/dhp_training.py --d-model 768 --layers 12 \\
      --vocab 32000 --steps 200  — slower on CPU, same code path.)
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse      # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax           # noqa: E402

from repro.api import ClusterSpec, Engine, get_strategy  # noqa: E402
from repro.configs import get_config                     # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internvl3-2b")
    ap.add_argument("--strategy", default="dhp",
                    help="dhp | dhp-faithful | static | oracle | ...")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--gbs", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=512)
    ap.add_argument("--mem-budget", type=float, default=900.0,
                    help="per-rank activation budget (tokens)")
    ap.add_argument("--dataset", default="openvid")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--compare-static", action="store_true")
    ap.add_argument("--save-plans", metavar="PATH", default=None,
                    help="write the executed Plan-IR trace to PATH")
    ap.add_argument("--replay-plans", metavar="PATH", default=None,
                    help="replay a saved trace (bit-identical groups)")
    ap.add_argument("--no-lookahead", action="store_true",
                    help="plan synchronously (disable the pipeline)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced().with_(family="dense", vlm=None)
    over = {}
    if args.d_model:
        over.update(d_model=args.d_model,
                    n_heads=max(4, args.d_model // 64), kv_heads=2,
                    d_ff=args.d_model * 4)
    if args.layers:
        over["n_layers"] = args.layers
    if args.vocab:
        over["vocab"] = args.vocab
    if over:
        cfg = cfg.with_(**over)

    cluster = ClusterSpec.auto(mem_budget=args.mem_budget)
    if args.replay_plans:
        from repro.api import ReplayStrategy, load_plans
        strategy = ReplayStrategy(plans=load_plans(args.replay_plans))
        args.steps = min(args.steps, len(strategy))
        print(f"replaying {args.steps} plans from {args.replay_plans}")
    else:
        strategy = args.strategy
    engine = Engine(cfg, cluster, strategy=strategy)
    print(f"devices={cluster.n_devices} arch={cfg.arch_id} "
          f"L={cfg.n_layers} d={cfg.d_model}")
    n_params = sum(p.size for p in jax.tree.leaves(engine.state.params))
    print(f"params: {n_params/1e6:.1f}M")

    if args.compare_static:
        # plan the same first batch with both strategies, run both
        # through the same executor — the live Fig.-2 contrast
        from repro.data.pipeline import HeterogeneousLoader
        data = next(iter(HeterogeneousLoader(
            args.dataset, args.gbs, cfg.vocab, seed=0,
            max_tokens=args.max_tokens, tokens_per_frame=16)))
        static = get_strategy("static").bind(
            engine.cost_model, cluster.n_replicas, args.mem_budget)
        splan = static.plan(data.infos)
        dplan = engine.plan(data)
        sm = engine.execute(splan, data, update=False)
        dm = engine.execute(dplan, data, update=False)
        print(f"   static-baseline loss={sm.loss:.4f} "
              f"est {splan.total_time_est:.3f}s "
              f"vs {args.strategy} est {dplan.total_time_est:.3f}s "
              f"(loss={dm.loss:.4f})")

    t_start = time.perf_counter()
    plan_log = [] if args.save_plans else None
    history = engine.train(
        steps=args.steps, dataset=args.dataset, global_batch=args.gbs,
        max_tokens=args.max_tokens,
        lookahead=not args.no_lookahead, plan_log=plan_log, log=print)
    total = time.perf_counter() - t_start
    hits = sum(m.plan_cache_hit for m in history)
    hidden = sum(m.plan_overlap_ms for m in history)
    print(f"\n{len(history)} steps in {total:.1f}s; "
          f"plan cache hits {hits}, {hidden:.1f}ms planning hidden; "
          f"executable pool: {engine.executor.pool.stats}")
    if plan_log is not None:
        from repro.api import save_plans
        save_plans(args.save_plans, plan_log)
        print(f"saved {len(plan_log)} plans -> {args.save_plans}")


if __name__ == "__main__":
    main()
