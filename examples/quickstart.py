"""Quickstart: build a model from the registry, train a few steps on
synthetic data, then decode from it. Pure CPU, < 1 minute.

  PYTHONPATH=src python examples/quickstart.py [--arch glm4-9b]
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402

from repro.configs import INPUT_SHAPES, get_config     # noqa: E402
from repro.data.pipeline import synthetic_batch        # noqa: E402
from repro.models.model import (decode_step, init_cache, init_params,
                                prefill, prefill_cross_kv)  # noqa: E402
from repro.training.optimizer import AdamW             # noqa: E402
from repro.training.train_step import (TrainState,
                                       make_train_step)  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()   # 2-layer CPU-sized variant
    print(f"arch={cfg.arch_id} family={cfg.family} "
          f"L={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.2f}M")

    opt = AdamW(lr=1e-3)
    state = TrainState(params, opt.init(params))
    step = jax.jit(make_train_step(cfg, opt))

    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=128,
                                global_batch=4)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, shape, seed=i).items()}
        state, metrics = step(state, batch)
        print(f"step {i}: loss={float(metrics['loss']):.4f}")

    # --- decode a few tokens -------------------------------------------
    if cfg.family in ("dense", "moe", "vlm"):
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_batch(cfg, shape, seed=0).items()}
        del batch["labels"]
        logits, cache = prefill(state.params, cfg, batch, cache_len=160)
    else:
        cache = init_cache(cfg, 4, 160)
        if cfg.family == "audio":
            b = synthetic_batch(cfg, shape, seed=0)
            cache = prefill_cross_kv(state.params, cfg,
                                     jnp.asarray(b["frames"]), cache)
    tok = jnp.zeros((4,), jnp.int32)
    toks = []
    for _ in range(8):
        lg, cache = decode_step(state.params, cfg, cache, tok)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)
        toks.append(int(tok[0]))
    print("decoded token ids:", toks)


if __name__ == "__main__":
    main()
