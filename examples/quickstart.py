"""Quickstart: the whole system through the unified `repro.api` engine —
build a Session, train a few DHP-scheduled steps on synthetic
heterogeneous data, then decode from the trained weights. Pure CPU,
< 1 minute.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  PYTHONPATH=src python examples/quickstart.py [--arch glm4-9b]

(Single-device also works — every group just lands on one rank.)
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                                             # noqa: E402

from repro.api import ClusterSpec, Engine              # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--strategy", default="dhp")
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args()

    # 1. a cluster spec: devices + model axis + per-rank token budget
    cluster = ClusterSpec.auto(mem_budget=900.0)
    print(f"devices={cluster.n_devices} ranks={cluster.n_replicas}")

    # 2. a session: model x cluster x strategy
    engine = Engine(args.arch, cluster, strategy=args.strategy,
                    reduced=True)   # 2-layer CPU-sized variant
    cfg = engine.cfg
    print(f"arch={cfg.arch_id} family={cfg.family} "
          f"L={cfg.n_layers} d={cfg.d_model} vocab={cfg.vocab}")
    n_params = sum(p.size for p in jax.tree.leaves(engine.state.params))
    print(f"params: {n_params/1e6:.2f}M  strategy={engine.strategy.name}")

    # 3. train — ONE loop for every strategy, async planning built in
    history = engine.train(steps=args.steps, dataset="openvid",
                           global_batch=4, max_tokens=256, log=print)
    print(f"loss {history[0].loss:.4f} -> {history[-1].loss:.4f}")

    # 3b. Plan-IR telemetry: how much planning the lookahead pipeline
    # hid, how often recurring batch shapes skipped the solver, and how
    # many communication-group slots actually had to be (re)created.
    hits = sum(m.plan_cache_hit for m in history)
    hidden = sum(m.plan_overlap_ms for m in history)
    reconf = sum(m.groups_reconfigured for m in history)
    print(f"plan cache hits {hits}/{len(history)}, "
          f"{hidden:.1f}ms planning hidden by lookahead, "
          f"{reconf} group slots reconfigured")

    # 4. decode a few tokens from the trained weights
    toks, report = engine.serve(batch=4, prompt_len=32, gen_tokens=8)
    print(f"decoded token ids: {[int(t) for t in toks[0]]} "
          f"({report['ms_per_token']:.1f} ms/token)")


if __name__ == "__main__":
    main()
