"""Batched serving example: prefill a batch of prompts, then decode with
the ring-buffer KV cache (the decode_32k / long_500k code path, CPU-sized).

  python examples/serve_batched.py [--arch glm4-9b] [--window 64]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402

from repro.configs import get_config                   # noqa: E402
from repro.models.model import init_params, prefill    # noqa: E402
from repro.serving.serve_step import (cache_for_shape,
                                      greedy_generate,
                                      make_serve_step)  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window cache (sub-quadratic variant)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.window:
        cfg = cfg.with_(sliding_window=args.window)
    params = init_params(jax.random.PRNGKey(0), cfg)

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cfg, {"tokens": prompts},
                            cache_len=args.prompt_len + args.gen)
    first = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
    t_prefill = time.perf_counter() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"({t_prefill:.2f}s)  cache k: {cache['k'].shape}")

    t0 = time.perf_counter()
    out, cache = greedy_generate(params, cfg, cache, first, args.gen)
    t_dec = time.perf_counter() - t0
    per_tok = t_dec / args.gen * 1e3
    print(f"decoded {args.gen} tokens x {args.batch} streams "
          f"({per_tok:.1f} ms/token-step)")
    print("stream 0:", [int(t) for t in out[0][:16]])


if __name__ == "__main__":
    main()
