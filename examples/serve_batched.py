"""Serving walkthrough: the one-shot path vs the continuous-batching
runtime.

Part 1 — `Engine.serve`: prefill one fixed batch of prompts, decode with
the ring-buffer KV cache (the decode_32k / long_500k code path,
CPU-sized). Every stream decodes until the longest is done.

Part 2 — `Engine.serving()`: the DHP-aware runtime. A heterogeneous
trace of requests (ragged prompt lengths, ragged output lengths,
arrival times) flows through iteration-level continuous batching:
prompts are chunk-prefilled under plans from the SAME DHP planner that
schedules training batches, decode slots recycle as requests finish,
and the paged KV manager gates admission.

  python examples/serve_batched.py [--arch glm4-9b] [--window 64]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.api import Engine, sample_trace                 # noqa: E402
from repro.configs import get_config                       # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window cache (sub-quadratic variant)")
    ap.add_argument("--requests", type=int, default=12,
                    help="trace length for the continuous-batching part")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.window:
        cfg = cfg.with_(sliding_window=args.window)
    engine = Engine(cfg, strategy="static", seed=0)

    # ---- part 1: the one-shot path ---------------------------------
    out, report = engine.serve(batch=args.batch,
                               prompt_len=args.prompt_len,
                               gen_tokens=args.gen)
    print(f"one-shot: batch={report['batch']} "
          f"len={report['prompt_len']} ({report['prefill_s']:.2f}s "
          f"prefill, {report['ms_per_token']:.1f} ms/token-step, "
          f"compiled={report['exe_miss']})")
    print("stream 0:", [int(t) for t in out[0][:16]])

    # second call: the decode step comes out of the cluster's pooled
    # executable cache — no re-jit, lower latency
    out, report = engine.serve(batch=args.batch,
                               prompt_len=args.prompt_len,
                               gen_tokens=args.gen)
    print(f"second one-shot call: exe_miss={report['exe_miss']} "
          f"({report['ms_per_token']:.1f} ms/token-step)")

    # ---- part 2: continuous batching over a heterogeneous trace ----
    rng = np.random.default_rng(0)
    trace = sample_trace("openvid", args.requests, rng,
                         vocab=engine.cfg.vocab, max_prompt=96,
                         mean_new_tokens=12, max_new_tokens=32)
    lens = sorted(r.prompt_len for r in trace)
    print(f"\ntrace: {len(trace)} requests, prompt lens "
          f"{lens[0]}..{lens[-1]}, "
          f"{sum(r.max_new_tokens for r in trace)} total output tokens")

    srv = engine.serving(slots=4, prefill_chunk=32)
    rep = srv.run(trace)
    print("continuous:", rep.summary())
    print(f"  kv: peak={rep.peak_kv_blocks} blocks, "
          f"occupancy max={max(rep.kv_occupancy):.2f}, "
          f"cache_len={rep.cache_len}")
    print(f"  planner: {rep.schedule_ms:.1f}ms host planning, "
          f"plan_cache={rep.plan_cache}")

    # a second trace of the same shape reuses every executable
    rep2 = srv.run(sample_trace("openvid", args.requests, rng,
                                vocab=engine.cfg.vocab, max_prompt=96,
                                mean_new_tokens=12, max_new_tokens=32))
    print(f"second trace: compiled={rep2.exe_misses} "
          f"({rep2.tokens_per_s:.1f} tok/s)")


if __name__ == "__main__":
    main()
