"""Batched serving example through `Engine.serve`: prefill a batch of
prompts, then decode with the ring-buffer KV cache (the decode_32k /
long_500k code path, CPU-sized).

  python examples/serve_batched.py [--arch glm4-9b] [--window 64]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import Engine                           # noqa: E402
from repro.configs import get_config                   # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window cache (sub-quadratic variant)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    if args.window:
        cfg = cfg.with_(sliding_window=args.window)
    engine = Engine(cfg, strategy="static", seed=0)

    out, report = engine.serve(batch=args.batch,
                               prompt_len=args.prompt_len,
                               gen_tokens=args.gen)
    print(f"prefill: batch={report['batch']} "
          f"len={report['prompt_len']} ({report['prefill_s']:.2f}s)")
    print(f"decoded {args.gen} tokens x {args.batch} streams "
          f"({report['ms_per_token']:.1f} ms/token-step, "
          f"compiled={report['exe_miss']})")
    print("stream 0:", [int(t) for t in out[0][:16]])

    # second call: the decode step comes out of the cluster's pooled
    # executable cache — no re-jit, lower latency
    out, report = engine.serve(batch=args.batch,
                               prompt_len=args.prompt_len,
                               gen_tokens=args.gen)
    print(f"second serve call: exe_miss={report['exe_miss']} "
          f"({report['ms_per_token']:.1f} ms/token-step)")


if __name__ == "__main__":
    main()
