"""Paper Table-4 / Fig.-2 case study: how each registered strategy
decomposes two batches with different length distributions into CP
groups, with an ASCII rendering of the static-vs-dynamic mesh occupancy.

Every planner is pulled from the `repro.api` strategy registry and
bound to the same cost model — adding a row to the comparison is one
`get_strategy(name)` call.

  python examples/case_study.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np                                     # noqa: E402

from repro.api import get_strategy                     # noqa: E402
from repro.core import (CostModel, analytic_coeffs, diff_plans,
                        sample_batch)                  # noqa: E402

N_RANKS = 32

# (label, registry name, constructor overrides)
LINEUP = [
    ("STATIC (Megatron-style)", "megatron", {}),
    ("DHP (paper-faithful)", "dhp-faithful", {}),
    ("DHP (+beyond-paper refinements)", "dhp", {}),
]


def render(plan, n_ranks, title, max_cols=64):
    print(f"\n{title} [{plan.strategy_name}]: "
          f"est {plan.total_time_est:.2f}s, "
          f"degrees {plan.degree_histogram}")
    scale = max(mb.makespan for mb in plan.micro_batches) or 1.0
    for i, mb in enumerate(plan.micro_batches[:8]):
        start = 0
        for g in mb.groups:
            width = max(1, int(g.est_time / scale * max_cols))
            bar = "#" * width
            lo = start % n_ranks
            print(f"  mb{i:<2d} ranks[{lo:2d}:{lo + g.degree:2d}] "
                  f"d={g.degree:<2d} |{bar:<{max_cols}}| "
                  f"{g.est_time:6.2f}s {len(g.seq_ids)} seqs")
            start += g.degree
    if len(plan.micro_batches) > 8:
        print(f"  ... +{len(plan.micro_batches) - 8} more micro-batches")


def main():
    cm = CostModel(analytic_coeffs(hidden=3584, n_layers=28, n_heads=28,
                                   kv_heads=4, ffn=18944, vocab=152000))
    budget = 3e9
    rng = np.random.default_rng(7)
    prev_plans = {}
    for case, ds in (("Case 1 (OpenVid-like, long-tailed)", "openvid"),
                     ("Case 2 (MSRVTT-like, uniform)", "msrvtt")):
        seqs = sample_batch(ds, 64, rng, max_tokens=262144)
        lens = sorted(s.length for s in seqs)
        print("=" * 72)
        print(f"{case}: {len(seqs)} seqs, median {lens[len(lens)//2]} "
              f"tokens, max {lens[-1]}")
        plans = {}
        for label, name, overrides in LINEUP:
            strat = get_strategy(name, **overrides).bind(
                cm, N_RANKS, budget)
            plans[label] = strat.plan(seqs)
            render(plans[label], N_RANKS, label)
            # GroupDelta vs the same strategy's previous-case plan: how
            # much of the communication-group layout survives a shift in
            # the length distribution (what the GroupPool reuses).
            delta = diff_plans(prev_plans.get(label), plans[label],
                               N_RANKS)
            print(f"    delta vs previous batch: {delta.summary()}")
            prev_plans[label] = plans[label]
        static_t = plans[LINEUP[0][0]].total_time_est
        print(f"\n  speedup faithful: "
              f"{static_t / plans[LINEUP[1][0]].total_time_est:.2f}x,"
              f" optimized: "
              f"{static_t / plans[LINEUP[2][0]].total_time_est:.2f}x")


if __name__ == "__main__":
    main()
